//! Small dense complex matrices (DMD operators: r ≤ m ≤ ~20) with LU
//! solve — used for the Koopman eigenvector back-transforms and the
//! least-squares mode-amplitude projection.

use super::complex::Cplx;

/// Dense row-major complex matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct CMat {
    rows: usize,
    cols: usize,
    data: Vec<Cplx>,
}

impl CMat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        CMat {
            rows,
            cols,
            data: vec![Cplx::ZERO; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = CMat::zeros(n, n);
        for i in 0..n {
            m.set(i, i, Cplx::ONE);
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> Cplx) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        CMat { rows, cols, data }
    }

    /// Promote a real matrix.
    pub fn from_real(m: &crate::tensor::Mat) -> Self {
        CMat::from_fn(m.rows(), m.cols(), |r, c| Cplx::real(m.get(r, c)))
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline(always)]
    pub fn get(&self, r: usize, c: usize) -> Cplx {
        self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn set(&mut self, r: usize, c: usize, v: Cplx) {
        self.data[r * self.cols + c] = v;
    }

    pub fn col(&self, c: usize) -> Vec<Cplx> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    /// Conjugate (Hermitian) transpose.
    pub fn hermitian(&self) -> CMat {
        CMat::from_fn(self.cols, self.rows, |r, c| self.get(c, r).conj())
    }

    pub fn matmul(&self, other: &CMat) -> CMat {
        assert_eq!(self.cols, other.rows);
        let mut out = CMat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self.get(i, k);
                if aik.re == 0.0 && aik.im == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    let v = out.get(i, j) + aik * other.get(k, j);
                    out.set(i, j, v);
                }
            }
        }
        out
    }

    pub fn matvec(&self, v: &[Cplx]) -> Vec<Cplx> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|r| {
                let mut acc = Cplx::ZERO;
                for c in 0..self.cols {
                    acc += self.get(r, c) * v[c];
                }
                acc
            })
            .collect()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().map(|z| z.abs()).fold(0.0, f64::max)
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|z| z.is_finite())
    }

    /// Solve A x = b via LU with partial pivoting. A must be square.
    pub fn solve(&self, b: &[Cplx]) -> anyhow::Result<Vec<Cplx>> {
        anyhow::ensure!(self.rows == self.cols, "solve: non-square {:?}", self.shape());
        anyhow::ensure!(self.rows == b.len(), "solve: rhs length mismatch");
        let n = self.rows;
        let mut lu = self.clone();
        let mut x: Vec<Cplx> = b.to_vec();
        let mut perm: Vec<usize> = (0..n).collect();

        for k in 0..n {
            // partial pivot
            let (mut pi, mut pmax) = (k, lu.get(k, k).abs());
            for r in k + 1..n {
                let a = lu.get(r, k).abs();
                if a > pmax {
                    pi = r;
                    pmax = a;
                }
            }
            anyhow::ensure!(pmax > 1e-300, "solve: singular matrix at pivot {k}");
            if pi != k {
                for c in 0..n {
                    let (a, b2) = (lu.get(k, c), lu.get(pi, c));
                    lu.set(k, c, b2);
                    lu.set(pi, c, a);
                }
                perm.swap(k, pi);
                x.swap(k, pi);
            }
            let pivot = lu.get(k, k);
            for r in k + 1..n {
                let factor = lu.get(r, k) / pivot;
                lu.set(r, k, factor);
                for c in k + 1..n {
                    let v = lu.get(r, c) - factor * lu.get(k, c);
                    lu.set(r, c, v);
                }
                let xv = x[r] - factor * x[k];
                x[r] = xv;
            }
        }
        // back substitution
        for r in (0..n).rev() {
            let mut acc = x[r];
            for c in r + 1..n {
                acc = acc - lu.get(r, c) * x[c];
            }
            x[r] = acc / lu.get(r, r);
        }
        Ok(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(re: f64, im: f64) -> Cplx {
        Cplx::new(re, im)
    }

    #[test]
    fn solve_identity() {
        let i = CMat::eye(4);
        let b = vec![c(1.0, 2.0), c(3.0, -1.0), c(0.0, 0.5), c(-2.0, 0.0)];
        let x = i.solve(&b).unwrap();
        for (got, want) in x.iter().zip(&b) {
            assert!((*got - *want).abs() < 1e-14);
        }
    }

    #[test]
    fn solve_roundtrip_random() {
        let mut rng = crate::rng::Rng::new(17);
        for n in [1usize, 2, 5, 12] {
            let a = CMat::from_fn(n, n, |_, _| c(rng.normal(), rng.normal()));
            let x_true: Vec<Cplx> = (0..n).map(|_| c(rng.normal(), rng.normal())).collect();
            let b = a.matvec(&x_true);
            let x = a.solve(&b).unwrap();
            for (got, want) in x.iter().zip(&x_true) {
                assert!((*got - *want).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn solve_singular_errors() {
        let a = CMat::zeros(3, 3);
        assert!(a.solve(&[Cplx::ONE; 3]).is_err());
    }

    #[test]
    fn hermitian_conjugates() {
        let a = CMat::from_fn(2, 3, |r, cc| c(r as f64, cc as f64));
        let h = a.hermitian();
        assert_eq!(h.shape(), (3, 2));
        assert_eq!(h.get(2, 1), c(1.0, -2.0));
    }

    #[test]
    fn matmul_identity() {
        let a = CMat::from_fn(3, 3, |r, cc| c((r + cc) as f64, (r * cc) as f64));
        let prod = a.matmul(&CMat::eye(3));
        assert_eq!(prod, a);
    }
}
