//! Zero-dependency inference serving for trained checkpoints.
//!
//! `dmdtrain serve` turns the repo's training half into a full
//! train-then-serve system: a pure-`std::net` HTTP/1.1 server (matching
//! the crate's offline, no-registry constraint) answers `POST /predict`
//! against named `DMDP` checkpoints. The moving parts:
//!
//! * [`registry::ModelRegistry`] — loads `<name>.dmdp` checkpoints (+
//!   optional arch/scaling sidecars) into immutable `Arc`-shared
//!   models, with hot reload (background poll and `POST /reload`);
//! * [`batcher::Batcher`] — coalesces concurrent predict requests
//!   inside a configurable window into one GEMM on the shared
//!   [`crate::util::pool::WorkerPool`];
//! * [`router`] — `/predict`, `/models`, `/healthz`, `/readyz`,
//!   `/metrics` (Prometheus counters + latency histograms from
//!   [`crate::metrics::serve`]);
//! * [`http`] — the minimal HTTP/1.1 request/response codec;
//! * [`admission`] — queue pressure (computed `Retry-After`) and
//!   per-model in-flight budgets;
//! * [`breaker`] — per-model circuit breaker quarantining checkpoints
//!   that keep panicking or failing to reload.
//!
//! ## Overload & lifecycle
//!
//! Requests carry an optional deadline (`serve.request_timeout_ms`
//! and/or `X-Deadline-Ms`); expired jobs are shed before the GEMM with
//! 503. The queue is bounded (`serve.max_queue_jobs`) with a bounded
//! submit wait (`serve.submit_wait_ms`) — saturation sheds with 429 and
//! a `Retry-After` computed from queue depth over drain rate. A
//! graceful stop first *drains*: the listener closes, `/readyz` flips
//! to `draining`, keep-alive is downgraded, and in-flight work gets
//! `serve.drain_timeout_ms` to finish before connections are
//! force-closed.
//!
//! ## Threading & determinism
//!
//! Connection handling is thread-per-connection, capped at
//! `serve.threads` concurrent handlers; HTTP threads only parse and
//! encode. All GEMM work funnels through the *single* batcher thread
//! onto the worker pool, so predict dispatches never contend with each
//! other. The native predict kernel accumulates each output row in a
//! fixed order independent of the other rows in the batch (see
//! [`crate::linalg::gemm`]), and JSON floats use shortest-roundtrip
//! formatting — a served prediction is **bit-identical** to calling
//! `Executable::predict` directly on the same checkpoint, regardless of
//! batch coalescing, thread count, or concurrent traffic.

pub mod admission;
pub mod batcher;
pub mod breaker;
pub mod http;
pub mod registry;
pub mod router;

pub use admission::{InflightBudget, QueuePressure};
pub use batcher::{Batcher, BatcherConfig, BatcherHandle, PredictFail};
pub use breaker::{Admission, CircuitBreaker};
pub use registry::{ModelRegistry, ReloadReport, ServedModel};
pub use router::AppState;

use crate::config::ServeConfig;
use crate::metrics::serve::ServeMetrics;
use std::collections::{BTreeSet, HashMap};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Charge reload failures to each model's circuit breaker (and clear
/// strikes for models that loaded cleanly). Scan-level errors carry the
/// `<scan>` pseudo-name and strike nothing.
fn note_reload_outcome(breaker: &CircuitBreaker, metrics: &ServeMetrics, report: &ReloadReport) {
    for name in &report.loaded {
        breaker.record_success(name);
    }
    for (name, err) in &report.errors {
        if name == "<scan>" {
            continue;
        }
        if breaker.record_failure(name) {
            metrics.breaker_opens.inc();
            eprintln!("serve: circuit breaker opened for model '{name}' (reload: {err})");
        }
    }
}

/// Counting gate: caps concurrent connection handlers and lets shutdown
/// wait for all of them to finish.
struct Gate {
    cap: usize,
    count: Mutex<usize>,
    cv: Condvar,
}

impl Gate {
    fn new(cap: usize) -> Gate {
        Gate {
            cap: cap.max(1),
            count: Mutex::new(0),
            cv: Condvar::new(),
        }
    }

    fn enter(&self) {
        let mut n = self.count.lock().unwrap();
        while *n >= self.cap {
            n = self.cv.wait(n).unwrap();
        }
        *n += 1;
    }

    fn leave(&self) {
        let mut n = self.count.lock().unwrap();
        *n -= 1;
        self.cv.notify_all();
    }

    fn wait_idle(&self) {
        let mut n = self.count.lock().unwrap();
        while *n > 0 {
            n = self.cv.wait(n).unwrap();
        }
    }

    /// Wait for all handlers to finish, giving up after `timeout`.
    /// Returns `true` when the gate went idle (clean drain).
    fn wait_idle_timeout(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut n = self.count.lock().unwrap();
        while *n > 0 {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            n = self.cv.wait_timeout(n, deadline - now).unwrap().0;
        }
        true
    }

    fn active(&self) -> usize {
        *self.count.lock().unwrap()
    }
}

/// Leave the gate even if the handler panics.
struct GateGuard(Arc<Gate>);

impl Drop for GateGuard {
    fn drop(&mut self) {
        self.0.leave();
    }
}

/// Live-connection registry so shutdown stays bounded. The per-read
/// idle timeout resets on every byte, so a byte-at-a-time client could
/// otherwise pin `Gate::wait_idle` indefinitely; `stop()` force-closes
/// every tracked socket instead, which makes blocked reads and writes
/// error out immediately.
struct ConnTracker {
    next_id: AtomicU64,
    conns: Mutex<HashMap<u64, TcpStream>>,
}

impl ConnTracker {
    fn new() -> ConnTracker {
        ConnTracker {
            next_id: AtomicU64::new(0),
            conns: Mutex::new(HashMap::new()),
        }
    }

    /// Track a handler's stream via a `try_clone` (the clone shares the
    /// socket, so shutting it down unblocks the handler's own reads).
    /// `None` when the clone fails — the handler still runs, just
    /// without forced-close coverage.
    fn register(&self, stream: &TcpStream) -> Option<u64> {
        let dup = stream.try_clone().ok()?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.conns
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(id, dup);
        Some(id)
    }

    fn deregister(&self, id: u64) {
        self.conns
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .remove(&id);
    }

    /// Force-close every tracked connection.
    fn shutdown_all(&self) {
        for s in self.conns.lock().unwrap_or_else(|e| e.into_inner()).values() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }
}

/// Deregister even if the handler panics.
struct ConnGuard {
    tracker: Arc<ConnTracker>,
    id: Option<u64>,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        if let Some(id) = self.id {
            self.tracker.deregister(id);
        }
    }
}

/// Exponential backoff state for the background registry-reload poll,
/// with once-per-streak failure logging: each distinct `name: error`
/// pair surfaces the first time it appears in a failure streak, then is
/// muted until a clean pass resets the streak (so a persistently broken
/// checkpoint doesn't spam one line per poll).
struct ReloadBackoff {
    base: Duration,
    streak: u32,
    seen: BTreeSet<String>,
}

/// What one reload pass decided: how long to wait, what to log.
struct ReloadPass {
    /// Wait before the next reload attempt.
    delay: Duration,
    /// Error lines to log — first appearance in this streak only.
    log: Vec<String>,
    /// True when a failing streak just ended.
    recovered: bool,
}

impl ReloadBackoff {
    fn new(base: Duration) -> ReloadBackoff {
        ReloadBackoff {
            base,
            streak: 0,
            seen: BTreeSet::new(),
        }
    }

    /// Digest one reload pass. Failures stretch the next delay to
    /// `base × 2^(streak-1)` capped at ×32; a clean pass resets the
    /// delay, the streak, and the logged-error memory.
    fn on_pass(&mut self, errors: &[(String, String)]) -> ReloadPass {
        if errors.is_empty() {
            let recovered = self.streak > 0;
            self.streak = 0;
            self.seen.clear();
            return ReloadPass {
                delay: self.base,
                log: Vec::new(),
                recovered,
            };
        }
        self.streak += 1;
        let mut log = Vec::new();
        for (name, err) in errors {
            let line = format!("{name}: {err}");
            if self.seen.insert(line.clone()) {
                log.push(line);
            }
        }
        ReloadPass {
            delay: self.base * (1u32 << (self.streak - 1).min(5)),
            log,
            recovered: false,
        }
    }
}

/// A running inference server. Dropping (or calling [`Server::shutdown`])
/// stops accepting, flips `/readyz` to `draining`, gives in-flight
/// handlers `serve.drain_timeout_ms` to finish, then force-closes
/// stragglers and joins the batcher and reload threads.
pub struct Server {
    addr: SocketAddr,
    state: Arc<AppState>,
    shutdown: Arc<AtomicBool>,
    gate: Arc<Gate>,
    tracker: Arc<ConnTracker>,
    drain_timeout: Duration,
    stopped: bool,
    accept_thread: Option<JoinHandle<()>>,
    reload_thread: Option<JoinHandle<()>>,
    /// Dropped last (after connections drain) so every in-flight predict
    /// is answered.
    batcher: Option<Batcher>,
}

impl Server {
    /// Bind, load the model registry, and start serving. `port = 0`
    /// binds an ephemeral port (read it back from [`Server::addr`]).
    pub fn start(cfg: &ServeConfig) -> anyhow::Result<Server> {
        let (registry, report) = ModelRegistry::open(&cfg.model_dir);
        for (name, err) in &report.errors {
            eprintln!("serve: model '{name}' failed to load: {err}");
        }
        let registry = Arc::new(registry);
        let metrics = Arc::new(ServeMetrics::new());
        let breaker = Arc::new(CircuitBreaker::new());
        let batcher = Batcher::start(
            BatcherConfig {
                window: Duration::from_micros(cfg.batch_window_us),
                max_rows: cfg.max_batch_rows,
                max_queue: cfg.max_queue_jobs.max(1),
                submit_wait: Duration::from_millis(cfg.submit_wait_ms),
            },
            Arc::clone(&metrics),
            Arc::clone(&breaker),
        );
        let state = Arc::new(AppState {
            registry: Arc::clone(&registry),
            metrics,
            started: std::time::Instant::now(),
            draining: Arc::new(AtomicBool::new(false)),
            reload_streak: Arc::new(AtomicU32::new(0)),
            breaker,
            budget: InflightBudget::new(cfg.per_model_inflight),
            request_timeout: (cfg.request_timeout_ms > 0)
                .then(|| Duration::from_millis(cfg.request_timeout_ms)),
        });

        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))
            .map_err(|e| anyhow::anyhow!("bind {}:{}: {e}", cfg.host, cfg.port))?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let gate = Arc::new(Gate::new(cfg.threads));
        let tracker = Arc::new(ConnTracker::new());

        let idle_timeout = Duration::from_millis(cfg.idle_timeout_ms.max(1));
        let accept_thread = {
            let state = Arc::clone(&state);
            let shutdown = Arc::clone(&shutdown);
            let gate = Arc::clone(&gate);
            let tracker = Arc::clone(&tracker);
            let handle = batcher.handle();
            std::thread::Builder::new()
                .name("dmdtrain-accept".to_string())
                .spawn(move || {
                    accept_loop(listener, state, handle, shutdown, gate, tracker, idle_timeout)
                })
                .map_err(|e| anyhow::anyhow!("spawn accept thread: {e}"))?
        };

        let reload_thread = if cfg.reload_secs > 0 {
            let registry = Arc::clone(&registry);
            let metrics = Arc::clone(&state.metrics);
            let shutdown = Arc::clone(&shutdown);
            let breaker = Arc::clone(&state.breaker);
            let reload_streak = Arc::clone(&state.reload_streak);
            let period = Duration::from_secs(cfg.reload_secs);
            Some(
                std::thread::Builder::new()
                    .name("dmdtrain-reload".to_string())
                    .spawn(move || {
                        let mut last = std::time::Instant::now();
                        let mut backoff = ReloadBackoff::new(period);
                        let mut delay = period;
                        while !shutdown.load(Ordering::Relaxed) {
                            std::thread::sleep(Duration::from_millis(50));
                            if last.elapsed() < delay {
                                continue;
                            }
                            last = std::time::Instant::now();
                            let report = registry.reload();
                            metrics.registry_reloads.inc();
                            note_reload_outcome(&breaker, &metrics, &report);
                            let pass = backoff.on_pass(&report.errors);
                            delay = pass.delay;
                            // surfaces in /readyz as `degraded` while a
                            // failure streak is alive
                            reload_streak.store(backoff.streak, Ordering::Relaxed);
                            for line in &pass.log {
                                eprintln!(
                                    "serve: reload failed ({line}); retrying in {delay:?}"
                                );
                            }
                            if pass.recovered {
                                eprintln!("serve: registry reload recovered");
                            }
                            if report.changed() {
                                eprintln!(
                                    "serve: registry reloaded ({} loaded, {} dropped)",
                                    report.loaded.len(),
                                    report.dropped.len()
                                );
                            }
                        }
                    })
                    .map_err(|e| anyhow::anyhow!("spawn reload thread: {e}"))?,
            )
        } else {
            None
        };

        Ok(Server {
            addr,
            state,
            shutdown,
            gate,
            tracker,
            drain_timeout: Duration::from_millis(cfg.drain_timeout_ms),
            stopped: false,
            accept_thread: Some(accept_thread),
            reload_thread,
            batcher: Some(batcher),
        })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn registry(&self) -> Arc<ModelRegistry> {
        Arc::clone(&self.state.registry)
    }

    pub fn metrics(&self) -> Arc<ServeMetrics> {
        Arc::clone(&self.state.metrics)
    }

    /// Block on the accept loop — the CLI foreground mode. Only returns
    /// if the listener fails; normal exit is process termination.
    pub fn wait(mut self) {
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    /// Graceful stop: no new connections, drain in-flight handlers,
    /// answer queued predicts, join all threads.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if std::mem::replace(&mut self.stopped, true) {
            return;
        }
        // Phase 1 — drain. Flip /readyz to `draining` (load balancers
        // pull the instance), close the listener (new connects are
        // refused), downgrade keep-alive so handlers exit after their
        // current request, and give in-flight work a bounded grace
        // period to finish.
        self.state.draining.store(true, Ordering::Relaxed);
        // unblock accept() with a dummy connection; the accept loop
        // exits and drops the listener
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if !self.gate.wait_idle_timeout(self.drain_timeout) {
            eprintln!(
                "serve: drain timed out after {:?} with {} handler(s) live; force-closing",
                self.drain_timeout,
                self.gate.active()
            );
        }
        // Phase 2 — force-close. Stragglers (slow clients, dozing
        // keep-alive sockets) are cut so a byte-at-a-time peer cannot
        // pin shutdown indefinitely.
        self.shutdown.store(true, Ordering::Relaxed);
        self.tracker.shutdown_all();
        self.gate.wait_idle();
        self.batcher = None; // joins the dispatcher (answers queued jobs)
        if let Some(t) = self.reload_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(
    listener: TcpListener,
    state: Arc<AppState>,
    batcher: BatcherHandle,
    shutdown: Arc<AtomicBool>,
    gate: Arc<Gate>,
    tracker: Arc<ConnTracker>,
    idle_timeout: Duration,
) {
    let stopping =
        |state: &AppState, shutdown: &AtomicBool| -> bool {
            shutdown.load(Ordering::Relaxed) || state.draining.load(Ordering::Relaxed)
        };
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if stopping(&state, &shutdown) {
                    break;
                }
                // transient accept failure (e.g. EMFILE) — back off
                // instead of hot-spinning
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if stopping(&state, &shutdown) {
            break; // the wake-up connection from stop()
        }
        gate.enter();
        let guard = GateGuard(Arc::clone(&gate));
        let conn_guard = ConnGuard {
            id: tracker.register(&stream),
            tracker: Arc::clone(&tracker),
        };
        let state = Arc::clone(&state);
        let batcher = batcher.clone();
        let shutdown = Arc::clone(&shutdown);
        // On spawn failure the closure comes back inside the error and
        // is dropped, which releases the gate slot and the connection
        // registration via the guards.
        let _ = std::thread::Builder::new()
            .name("dmdtrain-conn".to_string())
            .spawn(move || {
                let _guard = guard;
                let _conn_guard = conn_guard;
                handle_connection(stream, &state, &batcher, &shutdown, idle_timeout);
            });
    }
}

fn handle_connection(
    stream: TcpStream,
    state: &AppState,
    batcher: &BatcherHandle,
    shutdown: &AtomicBool,
    idle_timeout: Duration,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(idle_timeout));
    // A peer that stops draining its receive buffer must stall a
    // bounded time, not pin the handler thread forever on write.
    let _ = stream.set_write_timeout(Some(idle_timeout));
    let reader_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_half);
    let mut writer = stream;
    loop {
        if shutdown.load(Ordering::Relaxed) {
            break;
        }
        let req = match http::read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => break, // clean close
            Err(e) => {
                if !is_transport_error(&e) {
                    let _ = http::Response::error(400, &format!("bad request: {e}"))
                        .write_to(&mut writer, false);
                }
                break;
            }
        };
        // draining downgrades keep-alive: the current request is served
        // (with `Connection: close`), then the handler exits and frees
        // its gate slot for the drain to observe
        let keep_alive = req.keep_alive
            && !shutdown.load(Ordering::Relaxed)
            && !state.draining.load(Ordering::Relaxed);
        let resp = router::handle(state, batcher, &req);
        if resp.write_to(&mut writer, keep_alive).is_err() {
            break;
        }
        if !keep_alive {
            break;
        }
    }
}

/// Idle timeout / peer reset / EOF — close quietly instead of answering
/// 400 into a dead or dozing socket.
fn is_transport_error(e: &anyhow::Error) -> bool {
    e.source()
        .and_then(|s| s.downcast_ref::<std::io::Error>())
        .map(|io| {
            matches!(
                io.kind(),
                std::io::ErrorKind::WouldBlock
                    | std::io::ErrorKind::TimedOut
                    | std::io::ErrorKind::UnexpectedEof
                    | std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::ConnectionAborted
                    | std::io::ErrorKind::BrokenPipe
            )
        })
        .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reload_backoff_grows_logs_once_and_resets() {
        let base = Duration::from_secs(2);
        let mut b = ReloadBackoff::new(base);
        let errs = vec![("m".to_string(), "boom".to_string())];
        let p1 = b.on_pass(&errs);
        assert_eq!(p1.delay, base);
        assert_eq!(p1.log, vec!["m: boom".to_string()]);
        assert!(!p1.recovered);
        // same failure again: delay doubles, nothing new logged
        let p2 = b.on_pass(&errs);
        assert_eq!(p2.delay, base * 2);
        assert!(p2.log.is_empty());
        assert_eq!(b.on_pass(&errs).delay, base * 4);
        // a different failure mid-streak surfaces exactly once
        let errs2 = vec![
            ("m".to_string(), "boom".to_string()),
            ("n".to_string(), "bad magic".to_string()),
        ];
        let p4 = b.on_pass(&errs2);
        assert_eq!(p4.delay, base * 8);
        assert_eq!(p4.log, vec!["n: bad magic".to_string()]);
        // delay growth is capped at ×32
        for _ in 0..10 {
            assert!(b.on_pass(&errs).delay <= base * 32);
        }
        // clean pass: reset + recovery flag
        let clean = b.on_pass(&[]);
        assert_eq!(clean.delay, base);
        assert!(clean.recovered && clean.log.is_empty());
        // a second clean pass is not "recovered" again
        assert!(!b.on_pass(&[]).recovered);
        // after the reset the old failure logs again at base delay
        let p5 = b.on_pass(&errs);
        assert_eq!(p5.delay, base);
        assert_eq!(p5.log, vec!["m: boom".to_string()]);
    }

    #[test]
    fn conn_tracker_registers_and_guard_deregisters() {
        let tracker = Arc::new(ConnTracker::new());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let guard = ConnGuard {
            id: tracker.register(&stream),
            tracker: Arc::clone(&tracker),
        };
        assert!(guard.id.is_some());
        assert_eq!(tracker.conns.lock().unwrap().len(), 1);
        // shutdown_all leaves the entry in place (the guard owns removal)
        tracker.shutdown_all();
        assert_eq!(tracker.conns.lock().unwrap().len(), 1);
        drop(guard);
        assert_eq!(tracker.conns.lock().unwrap().len(), 0);
    }
}
