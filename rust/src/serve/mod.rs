//! Zero-dependency inference serving for trained checkpoints.
//!
//! `dmdtrain serve` turns the repo's training half into a full
//! train-then-serve system: a pure-`std::net` HTTP/1.1 server (matching
//! the crate's offline, no-registry constraint) answers `POST /predict`
//! against named `DMDP` checkpoints. The moving parts:
//!
//! * [`registry::ModelRegistry`] — loads `<name>.dmdp` checkpoints (+
//!   optional arch/scaling sidecars) into immutable `Arc`-shared
//!   models, with hot reload (background poll and `POST /reload`);
//! * [`batcher::Batcher`] — coalesces concurrent predict requests
//!   inside a configurable window into one GEMM on the shared
//!   [`crate::util::pool::WorkerPool`];
//! * [`router`] — `/predict`, `/models`, `/healthz`, `/metrics`
//!   (Prometheus counters + latency histograms from
//!   [`crate::metrics::serve`]);
//! * [`http`] — the minimal HTTP/1.1 request/response codec.
//!
//! ## Threading & determinism
//!
//! Connection handling is thread-per-connection, capped at
//! `serve.threads` concurrent handlers; HTTP threads only parse and
//! encode. All GEMM work funnels through the *single* batcher thread
//! onto the worker pool, so predict dispatches never contend with each
//! other. The native predict kernel accumulates each output row in a
//! fixed order independent of the other rows in the batch (see
//! [`crate::linalg::gemm`]), and JSON floats use shortest-roundtrip
//! formatting — a served prediction is **bit-identical** to calling
//! `Executable::predict` directly on the same checkpoint, regardless of
//! batch coalescing, thread count, or concurrent traffic.

pub mod batcher;
pub mod http;
pub mod registry;
pub mod router;

pub use batcher::{Batcher, BatcherConfig, BatcherHandle};
pub use registry::{ModelRegistry, ReloadReport, ServedModel};
pub use router::AppState;

use crate::config::ServeConfig;
use crate::metrics::serve::ServeMetrics;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Close keep-alive connections idle longer than this; also bounds how
/// long shutdown waits for an idle client.
const IDLE_TIMEOUT: Duration = Duration::from_secs(5);

/// Counting gate: caps concurrent connection handlers and lets shutdown
/// wait for all of them to finish.
struct Gate {
    cap: usize,
    count: Mutex<usize>,
    cv: Condvar,
}

impl Gate {
    fn new(cap: usize) -> Gate {
        Gate {
            cap: cap.max(1),
            count: Mutex::new(0),
            cv: Condvar::new(),
        }
    }

    fn enter(&self) {
        let mut n = self.count.lock().unwrap();
        while *n >= self.cap {
            n = self.cv.wait(n).unwrap();
        }
        *n += 1;
    }

    fn leave(&self) {
        let mut n = self.count.lock().unwrap();
        *n -= 1;
        self.cv.notify_all();
    }

    fn wait_idle(&self) {
        let mut n = self.count.lock().unwrap();
        while *n > 0 {
            n = self.cv.wait(n).unwrap();
        }
    }
}

/// Leave the gate even if the handler panics.
struct GateGuard(Arc<Gate>);

impl Drop for GateGuard {
    fn drop(&mut self) {
        self.0.leave();
    }
}

/// A running inference server. Dropping (or calling [`Server::shutdown`])
/// stops accepting, drains in-flight connections, then joins the batcher
/// and reload threads.
pub struct Server {
    addr: SocketAddr,
    state: Arc<AppState>,
    shutdown: Arc<AtomicBool>,
    gate: Arc<Gate>,
    accept_thread: Option<JoinHandle<()>>,
    reload_thread: Option<JoinHandle<()>>,
    /// Dropped last (after connections drain) so every in-flight predict
    /// is answered.
    batcher: Option<Batcher>,
}

impl Server {
    /// Bind, load the model registry, and start serving. `port = 0`
    /// binds an ephemeral port (read it back from [`Server::addr`]).
    pub fn start(cfg: &ServeConfig) -> anyhow::Result<Server> {
        let (registry, report) = ModelRegistry::open(&cfg.model_dir);
        for (name, err) in &report.errors {
            eprintln!("serve: model '{name}' failed to load: {err}");
        }
        let registry = Arc::new(registry);
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::start(
            BatcherConfig {
                window: Duration::from_micros(cfg.batch_window_us),
                max_rows: cfg.max_batch_rows,
            },
            Arc::clone(&metrics),
        );
        let state = Arc::new(AppState {
            registry: Arc::clone(&registry),
            metrics,
            started: std::time::Instant::now(),
        });

        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))
            .map_err(|e| anyhow::anyhow!("bind {}:{}: {e}", cfg.host, cfg.port))?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let gate = Arc::new(Gate::new(cfg.threads));

        let accept_thread = {
            let state = Arc::clone(&state);
            let shutdown = Arc::clone(&shutdown);
            let gate = Arc::clone(&gate);
            let handle = batcher.handle();
            std::thread::Builder::new()
                .name("dmdtrain-accept".to_string())
                .spawn(move || accept_loop(listener, state, handle, shutdown, gate))
                .map_err(|e| anyhow::anyhow!("spawn accept thread: {e}"))?
        };

        let reload_thread = if cfg.reload_secs > 0 {
            let registry = Arc::clone(&registry);
            let metrics = Arc::clone(&state.metrics);
            let shutdown = Arc::clone(&shutdown);
            let period = Duration::from_secs(cfg.reload_secs);
            Some(
                std::thread::Builder::new()
                    .name("dmdtrain-reload".to_string())
                    .spawn(move || {
                        let mut last = std::time::Instant::now();
                        while !shutdown.load(Ordering::Relaxed) {
                            std::thread::sleep(Duration::from_millis(50));
                            if last.elapsed() < period {
                                continue;
                            }
                            last = std::time::Instant::now();
                            let report = registry.reload();
                            metrics.registry_reloads.inc();
                            for (name, err) in &report.errors {
                                eprintln!("serve: reload of '{name}' failed: {err}");
                            }
                            if report.changed() {
                                eprintln!(
                                    "serve: registry reloaded ({} loaded, {} dropped)",
                                    report.loaded.len(),
                                    report.dropped.len()
                                );
                            }
                        }
                    })
                    .map_err(|e| anyhow::anyhow!("spawn reload thread: {e}"))?,
            )
        } else {
            None
        };

        Ok(Server {
            addr,
            state,
            shutdown,
            gate,
            accept_thread: Some(accept_thread),
            reload_thread,
            batcher: Some(batcher),
        })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn registry(&self) -> Arc<ModelRegistry> {
        Arc::clone(&self.state.registry)
    }

    pub fn metrics(&self) -> Arc<ServeMetrics> {
        Arc::clone(&self.state.metrics)
    }

    /// Block on the accept loop — the CLI foreground mode. Only returns
    /// if the listener fails; normal exit is process termination.
    pub fn wait(mut self) {
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    /// Graceful stop: no new connections, drain in-flight handlers,
    /// answer queued predicts, join all threads.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.shutdown.swap(true, Ordering::Relaxed) {
            return;
        }
        // unblock accept() with a dummy connection
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        self.gate.wait_idle();
        self.batcher = None; // joins the dispatcher
        if let Some(t) = self.reload_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(
    listener: TcpListener,
    state: Arc<AppState>,
    batcher: BatcherHandle,
    shutdown: Arc<AtomicBool>,
    gate: Arc<Gate>,
) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if shutdown.load(Ordering::Relaxed) {
                    break;
                }
                // transient accept failure (e.g. EMFILE) — back off
                // instead of hot-spinning
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if shutdown.load(Ordering::Relaxed) {
            break; // the wake-up connection from stop()
        }
        gate.enter();
        let guard = GateGuard(Arc::clone(&gate));
        let state = Arc::clone(&state);
        let batcher = batcher.clone();
        let shutdown = Arc::clone(&shutdown);
        // On spawn failure the closure comes back inside the error and
        // is dropped, which releases the gate slot via the guard.
        let _ = std::thread::Builder::new()
            .name("dmdtrain-conn".to_string())
            .spawn(move || {
                let _guard = guard;
                handle_connection(stream, &state, &batcher, &shutdown);
            });
    }
}

fn handle_connection(
    stream: TcpStream,
    state: &AppState,
    batcher: &BatcherHandle,
    shutdown: &AtomicBool,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(IDLE_TIMEOUT));
    let reader_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_half);
    let mut writer = stream;
    loop {
        if shutdown.load(Ordering::Relaxed) {
            break;
        }
        let req = match http::read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => break, // clean close
            Err(e) => {
                if !is_transport_error(&e) {
                    let _ = http::Response::error(400, &format!("bad request: {e}"))
                        .write_to(&mut writer, false);
                }
                break;
            }
        };
        let keep_alive = req.keep_alive && !shutdown.load(Ordering::Relaxed);
        let resp = router::handle(state, batcher, &req);
        if resp.write_to(&mut writer, keep_alive).is_err() {
            break;
        }
        if !keep_alive {
            break;
        }
    }
}

/// Idle timeout / peer reset / EOF — close quietly instead of answering
/// 400 into a dead or dozing socket.
fn is_transport_error(e: &anyhow::Error) -> bool {
    e.source()
        .and_then(|s| s.downcast_ref::<std::io::Error>())
        .map(|io| {
            matches!(
                io.kind(),
                std::io::ErrorKind::WouldBlock
                    | std::io::ErrorKind::TimedOut
                    | std::io::ErrorKind::UnexpectedEof
                    | std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::ConnectionAborted
                    | std::io::ErrorKind::BrokenPipe
            )
        })
        .unwrap_or(false)
}
