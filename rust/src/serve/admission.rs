//! Admission-control primitives for the serve stack: live queue
//! pressure (depth + EWMA drain rate) feeding a computed `Retry-After`
//! hint, and per-model in-flight budgets so one hot model cannot starve
//! every other entry in the registry.
//!
//! Everything here is lock-free or a single short-held mutex — these
//! types sit on the request path in front of the batcher queue, so they
//! must never block behind the GEMM.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Floor of the computed `Retry-After` hint.
pub const RETRY_AFTER_MIN_SECS: u64 = 1;

/// Ceiling of the computed `Retry-After` hint — beyond this the queue
/// estimate is noise and clients should just poll.
pub const RETRY_AFTER_MAX_SECS: u64 = 30;

/// `Retry-After` from observed queue state: the time to drain the
/// current backlog at the current drain rate, clamped to
/// [[`RETRY_AFTER_MIN_SECS`], [`RETRY_AFTER_MAX_SECS`]]. A backlog with
/// no measurable drain (wedged or freshly started dispatcher) pins the
/// hint at the ceiling.
pub fn retry_after_secs(queue_depth: usize, drain_rate_per_sec: f64) -> u64 {
    if queue_depth == 0 {
        return RETRY_AFTER_MIN_SECS;
    }
    if !(drain_rate_per_sec > 0.0) {
        return RETRY_AFTER_MAX_SECS;
    }
    let secs = (queue_depth as f64 / drain_rate_per_sec).ceil() as u64;
    secs.clamp(RETRY_AFTER_MIN_SECS, RETRY_AFTER_MAX_SECS)
}

/// Shared view of the predict queue: depth, jobs drained, and a
/// drain-rate EWMA the dispatcher refreshes. Request threads read it to
/// compute `Retry-After` and `/readyz` reads the brownout flag.
#[derive(Debug, Default)]
pub struct QueuePressure {
    depth: AtomicUsize,
    drained: AtomicU64,
    /// EWMA drain rate in jobs/sec × 1000 (fixed-point so it fits an
    /// atomic without a lock).
    rate_milli: AtomicU64,
    brownout: AtomicBool,
}

impl QueuePressure {
    pub fn new() -> QueuePressure {
        QueuePressure::default()
    }

    /// A job was accepted into the queue.
    pub fn enqueued(&self) {
        self.depth.fetch_add(1, Ordering::Relaxed);
    }

    /// A job left the queue answered (result, shed, or shutdown reply).
    pub fn job_done(&self) {
        // saturating: a dispatcher crash can drop jobs without a
        // matching `enqueued` bookkeeping path ever running again
        let _ = self
            .depth
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
                Some(d.saturating_sub(1))
            });
        self.drained.fetch_add(1, Ordering::Relaxed);
    }

    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Total jobs ever drained (monotonic; the dispatcher differentiates
    /// it to refresh the rate EWMA).
    pub fn drained(&self) -> u64 {
        self.drained.load(Ordering::Relaxed)
    }

    /// Smoothed drain rate in jobs/sec (0.0 until the first refresh).
    pub fn drain_rate(&self) -> f64 {
        self.rate_milli.load(Ordering::Relaxed) as f64 / 1000.0
    }

    pub fn set_drain_rate(&self, per_sec: f64) {
        let milli = if per_sec.is_finite() && per_sec > 0.0 {
            (per_sec * 1000.0).round() as u64
        } else {
            0
        };
        self.rate_milli.store(milli, Ordering::Relaxed);
    }

    pub fn in_brownout(&self) -> bool {
        self.brownout.load(Ordering::Relaxed)
    }

    pub fn set_brownout(&self, on: bool) {
        self.brownout.store(on, Ordering::Relaxed);
    }

    /// The computed client back-off hint for a shed response.
    pub fn retry_after_hint(&self) -> u64 {
        retry_after_secs(self.depth(), self.drain_rate())
    }
}

/// Per-model in-flight request budget (`serve.per_model_inflight`;
/// 0 = unlimited). Acquired by the router before submit and released by
/// the [`InflightGuard`] after the reply lands, so a model's slot count
/// covers its whole queue + GEMM residency.
#[derive(Debug)]
pub struct InflightBudget {
    cap: usize,
    counts: Mutex<HashMap<String, usize>>,
}

impl InflightBudget {
    pub fn new(cap: usize) -> Arc<InflightBudget> {
        Arc::new(InflightBudget {
            cap,
            counts: Mutex::new(HashMap::new()),
        })
    }

    /// Take a slot for `model`, or `None` when the model is at its cap
    /// (the router answers 429). A cap of 0 disables budgeting and
    /// hands out unguarded slots for free.
    pub fn try_acquire(self: &Arc<Self>, model: &str) -> Option<InflightGuard> {
        if self.cap == 0 {
            return Some(InflightGuard {
                budget: None,
                name: String::new(),
            });
        }
        let mut counts = self.counts.lock().unwrap_or_else(|e| e.into_inner());
        let n = counts.entry(model.to_string()).or_insert(0);
        if *n >= self.cap {
            return None;
        }
        *n += 1;
        Some(InflightGuard {
            budget: Some(Arc::clone(self)),
            name: model.to_string(),
        })
    }

    /// Current in-flight count for a model (tests / introspection).
    pub fn inflight(&self, model: &str) -> usize {
        self.counts
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(model)
            .copied()
            .unwrap_or(0)
    }
}

/// RAII slot from [`InflightBudget::try_acquire`]; carried inside the
/// `PredictJob` so the slot is held until the reply is sent (or the job
/// is shed), whichever thread that happens on.
#[derive(Debug)]
pub struct InflightGuard {
    budget: Option<Arc<InflightBudget>>,
    name: String,
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        let Some(budget) = self.budget.take() else {
            return;
        };
        let mut counts = budget.counts.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(n) = counts.get_mut(&self.name) {
            *n = n.saturating_sub(1);
            if *n == 0 {
                counts.remove(&self.name);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_after_is_depth_over_rate_clamped() {
        // empty queue: immediate retry
        assert_eq!(retry_after_secs(0, 100.0), RETRY_AFTER_MIN_SECS);
        // 100 queued at 50/s → 2 s
        assert_eq!(retry_after_secs(100, 50.0), 2);
        // exact division still rounds up from fractional seconds
        assert_eq!(retry_after_secs(75, 50.0), 2);
        // sub-second drain clamps to the floor
        assert_eq!(retry_after_secs(3, 1000.0), RETRY_AFTER_MIN_SECS);
        // huge backlog clamps to the ceiling
        assert_eq!(retry_after_secs(10_000, 10.0), RETRY_AFTER_MAX_SECS);
        // backlog with no measured drain (wedged dispatcher): ceiling
        assert_eq!(retry_after_secs(5, 0.0), RETRY_AFTER_MAX_SECS);
        assert_eq!(retry_after_secs(5, -1.0), RETRY_AFTER_MAX_SECS);
        assert_eq!(retry_after_secs(5, f64::NAN), RETRY_AFTER_MAX_SECS);
    }

    #[test]
    fn pressure_tracks_depth_rate_and_hint() {
        let p = QueuePressure::new();
        assert_eq!(p.depth(), 0);
        assert_eq!(p.retry_after_hint(), RETRY_AFTER_MIN_SECS);
        for _ in 0..6 {
            p.enqueued();
        }
        // backlog, no rate yet → ceiling
        assert_eq!(p.retry_after_hint(), RETRY_AFTER_MAX_SECS);
        p.set_drain_rate(2.0);
        assert_eq!(p.retry_after_hint(), 3); // ceil(6 / 2)
        p.job_done();
        p.job_done();
        assert_eq!(p.depth(), 4);
        assert_eq!(p.drained(), 2);
        assert_eq!(p.retry_after_hint(), 2); // ceil(4 / 2)
        // job_done never underflows even if bookkeeping desyncs
        for _ in 0..10 {
            p.job_done();
        }
        assert_eq!(p.depth(), 0);
    }

    #[test]
    fn budget_caps_per_model_and_releases_on_drop() {
        let b = InflightBudget::new(2);
        let g1 = b.try_acquire("hot").unwrap();
        let _g2 = b.try_acquire("hot").unwrap();
        assert!(b.try_acquire("hot").is_none(), "third slot refused");
        // a different model is unaffected by the hot model's cap
        let _other = b.try_acquire("cold").unwrap();
        assert_eq!(b.inflight("hot"), 2);
        drop(g1);
        assert_eq!(b.inflight("hot"), 1);
        assert!(b.try_acquire("hot").is_some(), "slot freed by drop");
    }

    #[test]
    fn zero_cap_disables_budgeting() {
        let b = InflightBudget::new(0);
        let guards: Vec<_> = (0..100).map(|_| b.try_acquire("m").unwrap()).collect();
        assert_eq!(b.inflight("m"), 0, "unlimited mode keeps no counts");
        drop(guards);
    }
}
