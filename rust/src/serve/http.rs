//! Minimal HTTP/1.1 message handling over blocking streams — just the
//! subset the inference endpoints need (request line, `Content-Length`,
//! `Connection`, fixed-length bodies, keep-alive). Zero external
//! dependencies, matching the crate's offline constraint.
//!
//! The parser is generic over [`BufRead`] so unit tests drive it from
//! in-memory cursors; the server feeds it `BufReader<TcpStream>`.

use std::io::{BufRead, Read, Write};

/// Refuse request bodies larger than this (8 MiB covers thousands of
/// paper-arch input rows with slack).
pub const MAX_BODY: usize = 8 * 1024 * 1024;
const MAX_HEADER_LINE: usize = 8192;
const MAX_HEADERS: usize = 64;

/// `read_line` through a `Take` so a peer streaming bytes with no
/// newline can never grow the buffer past the cap — the length check
/// happens *during* the read, not after it. `Ok(None)` = clean EOF
/// before any byte.
fn read_line_limited<R: BufRead>(reader: &mut R, cap: usize) -> anyhow::Result<Option<String>> {
    let mut line = String::new();
    let n = reader.by_ref().take(cap as u64 + 1).read_line(&mut line)?;
    if n == 0 {
        return Ok(None);
    }
    anyhow::ensure!(line.len() <= cap, "line exceeds {cap} bytes");
    Ok(Some(line))
}

/// One parsed request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Upper-cased method ("GET", "POST", …).
    pub method: String,
    /// Path with any query string stripped.
    pub path: String,
    pub body: Vec<u8>,
    /// Whether the client asked to keep the connection open
    /// (HTTP/1.1 default, overridable via `Connection:`).
    pub keep_alive: bool,
    /// Per-request latency budget from the `X-Deadline-Ms` header —
    /// the server sheds the request (503) once this expires in queue.
    pub deadline_ms: Option<u64>,
}

/// Read one request off the stream. `Ok(None)` means the peer closed
/// the connection cleanly before sending another request (keep-alive
/// end-of-stream); errors are malformed requests or transport failures.
pub fn read_request<R: BufRead>(reader: &mut R) -> anyhow::Result<Option<Request>> {
    let line = match read_line_limited(reader, MAX_HEADER_LINE)? {
        Some(l) => l,
        None => return Ok(None),
    };
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_ascii_uppercase();
    let raw_path = parts.next().unwrap_or("");
    let version = parts.next().unwrap_or("");
    anyhow::ensure!(
        !method.is_empty() && raw_path.starts_with('/'),
        "malformed request line {line:?}"
    );
    let path = raw_path.split('?').next().unwrap_or("/").to_string();

    let mut content_length = 0usize;
    let mut keep_alive = version != "HTTP/1.0";
    let mut deadline_ms = None;
    let mut terminated = false;
    for _ in 0..MAX_HEADERS {
        let h = read_line_limited(reader, MAX_HEADER_LINE)?
            .ok_or_else(|| anyhow::anyhow!("connection closed inside headers"))?;
        let h = h.trim_end();
        if h.is_empty() {
            terminated = true;
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let v = v.trim();
            match k.trim().to_ascii_lowercase().as_str() {
                "content-length" => {
                    content_length = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad Content-Length {v:?}"))?;
                }
                "connection" => {
                    let v = v.to_ascii_lowercase();
                    if v.contains("close") {
                        keep_alive = false;
                    } else if v.contains("keep-alive") {
                        keep_alive = true;
                    }
                }
                "x-deadline-ms" => {
                    // loud on garbage: a client that tried to set a
                    // budget should not silently get no budget
                    deadline_ms = Some(
                        v.parse()
                            .map_err(|_| anyhow::anyhow!("bad X-Deadline-Ms {v:?}"))?,
                    );
                }
                _ => {}
            }
        }
    }
    anyhow::ensure!(terminated, "too many headers");
    anyhow::ensure!(
        content_length <= MAX_BODY,
        "body too large ({content_length} bytes, max {MAX_BODY})"
    );
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Some(Request {
        method,
        path,
        body,
        keep_alive,
        deadline_ms,
    }))
}

/// One response to serialize.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Emitted as a `Retry-After: <secs>` header when set (load
    /// shedding: 429 responses carry the client back-off hint).
    pub retry_after: Option<u64>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.into_bytes(),
            retry_after: None,
        }
    }

    pub fn text(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into_bytes(),
            retry_after: None,
        }
    }

    /// JSON error envelope `{"error": "..."}` (message JSON-escaped).
    pub fn error(status: u16, msg: &str) -> Response {
        let body = format!(
            "{{\"error\":{}}}",
            crate::util::jsonl::Json::Str(msg.to_string()).encode()
        );
        Response::json(status, body)
    }

    /// Attach a `Retry-After: <secs>` header (builder-style).
    pub fn with_retry_after(mut self, secs: u64) -> Response {
        self.retry_after = Some(secs);
        self
    }

    /// Serialize status line + headers + body as one buffered write.
    pub fn write_to(&self, w: &mut impl Write, keep_alive: bool) -> std::io::Result<()> {
        let retry = match self.retry_after {
            Some(secs) => format!("Retry-After: {secs}\r\n"),
            None => String::new(),
        };
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n{}Connection: {}\r\n\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len(),
            retry,
            if keep_alive { "keep-alive" } else { "close" },
        );
        let mut buf = Vec::with_capacity(head.len() + self.body.len());
        buf.extend_from_slice(head.as_bytes());
        buf.extend_from_slice(&self.body);
        w.write_all(&buf)?;
        w.flush()
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Read one response off the stream — the client half, used by the
/// integration tests and `benches/serve_load.rs`. Returns
/// `(status, body)`.
pub fn read_response<R: BufRead>(reader: &mut R) -> anyhow::Result<(u16, Vec<u8>)> {
    let line = read_line_limited(reader, MAX_HEADER_LINE)?
        .ok_or_else(|| anyhow::anyhow!("connection closed before response"))?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("malformed status line {line:?}"))?;
    let mut content_length = 0usize;
    let mut terminated = false;
    for _ in 0..MAX_HEADERS {
        let h = read_line_limited(reader, MAX_HEADER_LINE)?
            .ok_or_else(|| anyhow::anyhow!("connection closed inside response headers"))?;
        let h = h.trim_end();
        if h.is_empty() {
            terminated = true;
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad response Content-Length"))?;
            }
        }
    }
    anyhow::ensure!(terminated, "too many response headers");
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_get_without_body() {
        let raw = b"GET /healthz?verbose=1 HTTP/1.1\r\nHost: x\r\n\r\n";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.body.is_empty());
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert_eq!(req.deadline_ms, None, "no header, no budget");
    }

    #[test]
    fn deadline_header_parses_and_rejects_garbage() {
        let raw = b"GET /healthz HTTP/1.1\r\nX-Deadline-Ms: 250\r\n\r\n";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap().unwrap();
        assert_eq!(req.deadline_ms, Some(250));
        // case-insensitive like every other header
        let raw = b"GET /healthz HTTP/1.1\r\nx-deadline-ms: 9\r\n\r\n";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap().unwrap();
        assert_eq!(req.deadline_ms, Some(9));
        // a client that tried to set a budget must not silently lose it
        let raw = b"GET /healthz HTTP/1.1\r\nX-Deadline-Ms: soon\r\n\r\n";
        assert!(read_request(&mut Cursor::new(&raw[..])).is_err());
    }

    #[test]
    fn parses_post_with_body_and_close() {
        let raw =
            b"POST /predict HTTP/1.1\r\nContent-Length: 4\r\nConnection: close\r\n\r\nabcd";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"abcd");
        assert!(!req.keep_alive);
    }

    #[test]
    fn http10_defaults_to_close() {
        let raw = b"GET / HTTP/1.0\r\n\r\n";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap().unwrap();
        assert!(!req.keep_alive);
    }

    #[test]
    fn eof_before_request_is_none() {
        assert!(read_request(&mut Cursor::new(&b""[..])).unwrap().is_none());
    }

    #[test]
    fn rejects_malformed_and_oversized() {
        assert!(read_request(&mut Cursor::new(&b"NONSENSE\r\n\r\n"[..])).is_err());
        let big = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        assert!(read_request(&mut Cursor::new(big.as_bytes())).is_err());
        assert!(read_request(&mut Cursor::new(
            &b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n"[..]
        ))
        .is_err());
        // truncated body
        assert!(read_request(&mut Cursor::new(
            &b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc"[..]
        ))
        .is_err());
    }

    #[test]
    fn newline_free_flood_is_capped_during_the_read() {
        // a peer streaming bytes with no '\n' must hit the line cap,
        // not grow the buffer until OOM
        let flood = vec![b'x'; MAX_HEADER_LINE * 4];
        assert!(read_request(&mut Cursor::new(&flood[..])).is_err());
        // same guard inside the header block
        let mut wire = b"GET / HTTP/1.1\r\n".to_vec();
        wire.extend(std::iter::repeat(b'h').take(MAX_HEADER_LINE * 4));
        assert!(read_request(&mut Cursor::new(&wire[..])).is_err());
    }

    #[test]
    fn response_roundtrip() {
        let resp = Response::json(200, "{\"ok\":true}".to_string());
        let mut wire = Vec::new();
        resp.write_to(&mut wire, true).unwrap();
        let (status, body) = read_response(&mut Cursor::new(&wire[..])).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"ok\":true}");
        let text = String::from_utf8(wire).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Connection: keep-alive"));
    }

    #[test]
    fn retry_after_header_is_emitted_on_shed() {
        let resp = Response::error(429, "overloaded").with_retry_after(1);
        let mut wire = Vec::new();
        resp.write_to(&mut wire, false).unwrap();
        let text = String::from_utf8(wire).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        // and absent when unset
        let plain = Response::json(200, "{}".to_string());
        let mut wire = Vec::new();
        plain.write_to(&mut wire, true).unwrap();
        assert!(!String::from_utf8(wire).unwrap().contains("Retry-After"));
    }

    #[test]
    fn error_body_is_escaped_json() {
        let resp = Response::error(400, "bad \"quote\"");
        let body = String::from_utf8(resp.body).unwrap();
        assert_eq!(body, "{\"error\":\"bad \\\"quote\\\"\"}");
    }
}
