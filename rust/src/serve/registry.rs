//! Checkpoint model registry: named `DMDP` checkpoints (plus optional
//! JSON sidecars carrying the manifest arch and input/output scaling)
//! loaded into immutable [`Arc`]-shared models, with hot reload of the
//! model directory.
//!
//! Layout: every `<name>.dmdp` file in the directory is one servable
//! model. The architecture is inferred from the checkpoint's
//! (weight, bias) tensor chain; an optional `<name>.json` sidecar can
//! pin the expected arch (`{"arch": [6, 8, 6]}` — load fails loudly on
//! mismatch, the corrupt-artifact guard), attach the dataset scaling
//! (`{"scaling": {"in": [[lo, hi], …], "out": [lo, hi]}}`) so the
//! server answers in physical units, and tag the workload the
//! checkpoint was trained on (`{"workload": "adr"}`) so one model
//! directory can serve checkpoints from different workloads side by
//! side, each with its own scaling.
//!
//! Reload semantics: a model whose file changed (mtime or size) is
//! re-loaded into a *new* `Arc` — in-flight requests keep the version
//! they resolved; a model that fails to load keeps serving its previous
//! version (fail loudly in the report, never panic, never drop a good
//! model for a bad file).

use crate::data::Scaling;
use crate::runtime::{Executable, ManifestEntry, NativeExecutable};
use crate::tensor::Tensor;
use crate::trainer::load_params;
use crate::util::jsonl::{parse, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, RwLock};
use std::time::SystemTime;

/// One immutable loaded model. Shared via `Arc`: request handlers and
/// the micro-batcher read it concurrently without locks.
pub struct ServedModel {
    pub name: String,
    pub arch: Vec<usize>,
    pub params: Vec<Tensor>,
    /// Native `predict` executable (dynamic batch) over the global pool.
    pub exe: Executable,
    /// Physical-units scaling; `None` serves the network's own space.
    pub scaling: Option<Scaling>,
    /// Workload the checkpoint was trained on (sidecar `"workload"`
    /// key); `None` for pre-workload sidecars and bare checkpoints.
    pub workload: Option<String>,
}

impl ServedModel {
    /// Build directly from parameter tensors (registry loads, tests and
    /// the load bench use this too).
    pub fn from_params(
        name: &str,
        params: Vec<Tensor>,
        scaling: Option<Scaling>,
    ) -> anyhow::Result<ServedModel> {
        let arch = infer_arch(&params)?;
        if let Some(s) = &scaling {
            anyhow::ensure!(
                s.in_ranges.len() == arch[0],
                "model '{name}': scaling has {} input ranges but arch {:?} expects {}",
                s.in_ranges.len(),
                arch,
                arch[0]
            );
        }
        let entry = ManifestEntry::native_model("predict", &format!("serve_{name}"), &arch, 0);
        let exe = Executable::Native(NativeExecutable::new(entry)?);
        Ok(ServedModel {
            name: name.to_string(),
            arch,
            params,
            exe,
            scaling,
            workload: None,
        })
    }

    pub fn n_in(&self) -> usize {
        self.arch[0]
    }

    pub fn n_out(&self) -> usize {
        *self.arch.last().unwrap()
    }

    pub fn param_count(&self) -> usize {
        self.params.iter().map(Tensor::len).sum()
    }

    /// Forward pass on any number of rows, applying the scaling (when
    /// present) on the way in and out. Scaling is an elementwise affine
    /// map, so predictions are row-independent — batching rows from
    /// different requests yields bit-identical outputs per row.
    pub fn predict(&self, x: &Tensor) -> anyhow::Result<Tensor> {
        match &self.scaling {
            None => self.exe.predict_all(&self.params, x),
            Some(s) => {
                let xs = s.scale_inputs(x);
                let ys = self.exe.predict_all(&self.params, &xs)?;
                Ok(s.unscale_outputs(&ys))
            }
        }
    }
}

/// Infer the layer widths from a checkpoint's flat `[w1, b1, …]` tensor
/// list, validating the (weight, bias) chain. This is the registry's
/// corrupt-artifact gate: it must error, not panic.
pub fn infer_arch(params: &[Tensor]) -> anyhow::Result<Vec<usize>> {
    anyhow::ensure!(
        !params.is_empty() && params.len() % 2 == 0,
        "checkpoint holds {} tensors — expected alternating (weight, bias) pairs",
        params.len()
    );
    let mut arch = vec![params[0].rows()];
    for l in 0..params.len() / 2 {
        let w = &params[2 * l];
        let b = &params[2 * l + 1];
        anyhow::ensure!(
            w.rows() == *arch.last().unwrap(),
            "layer {l}: weight rows {} do not chain from previous width {}",
            w.rows(),
            arch.last().unwrap()
        );
        anyhow::ensure!(
            b.rows() == 1 && b.cols() == w.cols(),
            "layer {l}: bias {:?} does not match weight columns {}",
            b.shape(),
            w.cols()
        );
        arch.push(w.cols());
    }
    anyhow::ensure!(
        arch.iter().all(|&d| d > 0),
        "zero-width layer in inferred arch {arch:?}"
    );
    Ok(arch)
}

/// (mtime, size) change detector for hot reload.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Fingerprint {
    mtime: SystemTime,
    len: u64,
}

impl Fingerprint {
    fn of(path: &Path) -> anyhow::Result<Fingerprint> {
        let meta = std::fs::metadata(path)?;
        Ok(Fingerprint {
            mtime: meta.modified()?,
            len: meta.len(),
        })
    }
}

struct LoadedEntry {
    model: Arc<ServedModel>,
    fingerprint: Fingerprint,
}

/// What one reload pass did.
#[derive(Debug, Default)]
pub struct ReloadReport {
    /// Models loaded or re-loaded this pass.
    pub loaded: Vec<String>,
    /// Models dropped because their file disappeared.
    pub dropped: Vec<String>,
    /// (model name, error) for files that failed to load — the previous
    /// version (if any) keeps serving.
    pub errors: Vec<(String, String)>,
}

impl ReloadReport {
    pub fn changed(&self) -> bool {
        !(self.loaded.is_empty() && self.dropped.is_empty())
    }
}

/// The registry: a model directory plus the currently loaded models.
pub struct ModelRegistry {
    dir: PathBuf,
    inner: RwLock<BTreeMap<String, LoadedEntry>>,
}

impl ModelRegistry {
    /// Open a registry over `dir` and run one load pass. A missing or
    /// empty directory is allowed (models can arrive later and be hot
    /// reloaded in); per-model load failures land in the report, not in
    /// the error return.
    pub fn open(dir: impl AsRef<Path>) -> (ModelRegistry, ReloadReport) {
        let reg = ModelRegistry {
            dir: dir.as_ref().to_path_buf(),
            inner: RwLock::new(BTreeMap::new()),
        };
        let report = reg.reload();
        (reg, report)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn get(&self, name: &str) -> Option<Arc<ServedModel>> {
        self.inner
            .read()
            .unwrap()
            .get(name)
            .map(|e| Arc::clone(&e.model))
    }

    /// The only model, when exactly one is loaded — lets `/predict`
    /// omit the "model" field in the single-model case.
    pub fn single(&self) -> Option<Arc<ServedModel>> {
        let inner = self.inner.read().unwrap();
        if inner.len() == 1 {
            inner.values().next().map(|e| Arc::clone(&e.model))
        } else {
            None
        }
    }

    pub fn list(&self) -> Vec<Arc<ServedModel>> {
        self.inner
            .read()
            .unwrap()
            .values()
            .map(|e| Arc::clone(&e.model))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Rescan the directory: load new checkpoints, re-load changed ones,
    /// drop removed ones. File IO happens outside the write lock so
    /// predicts are never blocked on disk.
    pub fn reload(&self) -> ReloadReport {
        let mut report = ReloadReport::default();

        // Snapshot current fingerprints under the read lock.
        let known: BTreeMap<String, Fingerprint> = self
            .inner
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.fingerprint))
            .collect();

        // Scan the directory. A missing dir means zero models; any
        // *other* read_dir failure (EMFILE under load, permissions
        // blips) aborts the pass so a transient error can never drop
        // every loaded model.
        let mut present: BTreeMap<String, PathBuf> = BTreeMap::new();
        match std::fs::read_dir(&self.dir) {
            Ok(entries) => {
                for entry in entries.flatten() {
                    let path = entry.path();
                    if path.extension().and_then(|e| e.to_str()) != Some("dmdp") {
                        continue;
                    }
                    let name = match path.file_stem().and_then(|s| s.to_str()) {
                        Some(s) if !s.is_empty() => s.to_string(),
                        _ => continue,
                    };
                    present.insert(name, path);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                report.errors.push((
                    "<scan>".to_string(),
                    format!("read_dir {}: {e}", self.dir.display()),
                ));
                return report;
            }
        }

        // Load new/changed models outside any lock.
        let mut fresh: Vec<(String, LoadedEntry)> = Vec::new();
        for (name, path) in &present {
            let fp = match Fingerprint::of(path) {
                Ok(fp) => fp,
                Err(e) => {
                    report.errors.push((name.clone(), format!("stat: {e}")));
                    continue;
                }
            };
            if known.get(name) == Some(&fp) {
                continue; // unchanged
            }
            match load_model(name, path) {
                Ok(model) => {
                    report.loaded.push(name.clone());
                    fresh.push((
                        name.clone(),
                        LoadedEntry {
                            model: Arc::new(model),
                            fingerprint: fp,
                        },
                    ));
                }
                Err(e) => report.errors.push((name.clone(), format!("{e:#}"))),
            }
        }

        // Apply under the write lock.
        {
            let mut inner = self.inner.write().unwrap();
            for (name, entry) in fresh {
                inner.insert(name, entry);
            }
            let gone: Vec<String> = inner
                .keys()
                .filter(|k| !present.contains_key(*k))
                .cloned()
                .collect();
            for name in gone {
                inner.remove(&name);
                report.dropped.push(name);
            }
        }
        report
    }
}

/// Load one checkpoint + optional sidecar into a model.
fn load_model(name: &str, path: &Path) -> anyhow::Result<ServedModel> {
    let params = load_params(path)?;
    let inferred = infer_arch(&params)?;
    let mut scaling = None;
    let mut workload = None;
    let sidecar = path.with_extension("json");
    if sidecar.exists() {
        let text = std::fs::read_to_string(&sidecar)
            .map_err(|e| anyhow::anyhow!("sidecar {}: {e}", sidecar.display()))?;
        let doc = parse(&text).map_err(|e| anyhow::anyhow!("sidecar {}: {e}", sidecar.display()))?;
        if let Some(a) = doc.get("arch") {
            let declared: Vec<usize> = a
                .as_arr()
                .map(|arr| arr.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default();
            anyhow::ensure!(
                declared == inferred,
                "sidecar declares arch {declared:?} but checkpoint tensors give {inferred:?}"
            );
        }
        if let Some(s) = doc.get("scaling") {
            scaling = Some(parse_scaling(s)?);
        }
        workload = doc.get("workload").and_then(Json::as_str).map(str::to_string);
    }
    let mut model = ServedModel::from_params(name, params, scaling)?;
    model.workload = workload;
    Ok(model)
}

/// Write the `<checkpoint>.json` sidecar next to a checkpoint so the
/// registry can pin the arch and serve in physical units
/// (`dmdtrain train --save-checkpoint` calls this with the dataset's
/// scaling). Float ranges use shortest-roundtrip formatting, so the
/// sidecar parses back to the exact f32 bounds. Written atomically
/// (tmp + fsync + rename, failpoint `"ckpt.sidecar"`) so a crash never
/// leaves a half-written sidecar next to a good checkpoint.
pub fn write_sidecar(
    checkpoint_path: impl AsRef<Path>,
    arch: &[usize],
    scaling: Option<&Scaling>,
    workload: Option<&str>,
) -> anyhow::Result<()> {
    use std::fmt::Write as _;
    let mut body = format!("{{\"arch\": {arch:?}");
    if let Some(w) = workload {
        let _ = write!(body, ", \"workload\": \"{w}\"");
    }
    if let Some(s) = scaling {
        body.push_str(", \"scaling\": {\"in\": [");
        for (i, &(lo, hi)) in s.in_ranges.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            let _ = write!(body, "[{}, {}]", lo as f64, hi as f64);
        }
        let _ = write!(
            body,
            "], \"out\": [{}, {}]}}",
            s.out_range.0 as f64, s.out_range.1 as f64
        );
    }
    body.push_str("}\n");
    let sidecar = checkpoint_path.as_ref().with_extension("json");
    crate::util::durable::atomic_write(&sidecar, "ckpt.sidecar", body.as_bytes())
        .map_err(|e| anyhow::anyhow!("sidecar {}: {e}", sidecar.display()))?;
    Ok(())
}

fn parse_scaling(s: &Json) -> anyhow::Result<Scaling> {
    let pair = |j: &Json, what: &str| -> anyhow::Result<(f32, f32)> {
        let arr = j
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("scaling.{what}: expected [lo, hi]"))?;
        anyhow::ensure!(arr.len() == 2, "scaling.{what}: expected [lo, hi]");
        let lo = arr[0]
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("scaling.{what}: non-numeric bound"))?;
        let hi = arr[1]
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("scaling.{what}: non-numeric bound"))?;
        Ok((lo as f32, hi as f32))
    };
    let in_arr = s
        .get("in")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("scaling: missing \"in\" range list"))?;
    let mut in_ranges = Vec::with_capacity(in_arr.len());
    for r in in_arr {
        in_ranges.push(pair(r, "in")?);
    }
    let out_range = pair(
        s.get("out")
            .ok_or_else(|| anyhow::anyhow!("scaling: missing \"out\" range"))?,
        "out",
    )?;
    Ok(Scaling {
        in_ranges,
        out_range,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Arch;
    use crate::rng::Rng;
    use crate::trainer::save_params;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dmdtrain_registry_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_model(dir: &Path, name: &str, dims: Vec<usize>, seed: u64) -> Vec<Tensor> {
        let arch = Arch::new(dims).unwrap();
        let params = arch.init_params(&mut Rng::new(seed));
        save_params(&params, dir.join(format!("{name}.dmdp"))).unwrap();
        params
    }

    #[test]
    fn infer_arch_from_checkpoint_tensors() {
        let arch = Arch::new(vec![6, 8, 6]).unwrap();
        let params = arch.init_params(&mut Rng::new(1));
        assert_eq!(infer_arch(&params).unwrap(), vec![6, 8, 6]);
        // broken chains error, never panic
        assert!(infer_arch(&params[..1]).is_err(), "odd tensor count");
        let mut bad = params.clone();
        bad[1] = Tensor::zeros(2, 8); // bias with wrong rows
        assert!(infer_arch(&bad).is_err());
        let mut unchained = params;
        unchained[2] = Tensor::zeros(9, 6); // w2 rows != w1 cols
        assert!(infer_arch(&unchained).is_err());
    }

    #[test]
    fn open_loads_and_predicts() {
        let dir = temp_dir("open");
        let params = write_model(&dir, "m", vec![4, 5, 3], 7);
        let (reg, report) = ModelRegistry::open(&dir);
        assert_eq!(report.loaded, vec!["m".to_string()]);
        assert!(report.errors.is_empty());
        let model = reg.get("m").expect("model loaded");
        assert_eq!(model.arch, vec![4, 5, 3]);
        assert_eq!(reg.single().unwrap().name, "m");

        let x = Tensor::from_fn(2, 4, |r, c| (r * 4 + c) as f32 * 0.1 - 0.3);
        let served = model.predict(&x).unwrap();
        let direct = model.exe.predict_all(&params, &x).unwrap();
        assert_eq!(served, direct, "registry predict matches direct predict");
    }

    #[test]
    fn corrupt_checkpoint_reports_error_not_panic() {
        let dir = temp_dir("corrupt");
        std::fs::write(dir.join("bad.dmdp"), b"DMDPgarbage").unwrap();
        let (reg, report) = ModelRegistry::open(&dir);
        assert!(reg.is_empty());
        assert_eq!(report.errors.len(), 1);
        assert_eq!(report.errors[0].0, "bad");
    }

    #[test]
    fn sidecar_arch_mismatch_fails_loudly() {
        let dir = temp_dir("sidecar");
        write_model(&dir, "m", vec![3, 4, 2], 1);
        std::fs::write(dir.join("m.json"), r#"{"arch": [3, 9, 2]}"#).unwrap();
        let (reg, report) = ModelRegistry::open(&dir);
        assert!(reg.is_empty());
        assert_eq!(report.errors.len(), 1);
        assert!(report.errors[0].1.contains("arch"));
    }

    #[test]
    fn sidecar_scaling_applies() {
        let dir = temp_dir("scaled");
        let params = write_model(&dir, "m", vec![2, 4, 1], 3);
        std::fs::write(
            dir.join("m.json"),
            r#"{"arch": [2, 4, 1], "scaling": {"in": [[0, 10], [-1, 1]], "out": [0, 100]}}"#,
        )
        .unwrap();
        let (reg, report) = ModelRegistry::open(&dir);
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        let model = reg.get("m").unwrap();
        let s = model.scaling.as_ref().unwrap();
        assert_eq!(s.in_ranges, vec![(0.0, 10.0), (-1.0, 1.0)]);
        assert_eq!(s.out_range, (0.0, 100.0));

        let x = Tensor::from_vec(1, 2, vec![5.0, 0.5]);
        let served = model.predict(&x).unwrap();
        let manual = {
            let xs = s.scale_inputs(&x);
            let ys = model.exe.predict_all(&params, &xs).unwrap();
            s.unscale_outputs(&ys)
        };
        assert_eq!(served, manual);
    }

    #[test]
    fn hot_reload_adds_updates_and_drops() {
        let dir = temp_dir("reload");
        write_model(&dir, "a", vec![3, 4, 2], 1);
        let (reg, _) = ModelRegistry::open(&dir);
        assert_eq!(reg.len(), 1);
        let a_v1 = reg.get("a").unwrap();

        // unchanged file → no reload, same Arc
        let rep = reg.reload();
        assert!(!rep.changed());
        assert!(Arc::ptr_eq(&a_v1, &reg.get("a").unwrap()));

        // new model appears
        write_model(&dir, "b", vec![5, 6, 4], 2);
        let rep = reg.reload();
        assert_eq!(rep.loaded, vec!["b".to_string()]);
        assert_eq!(reg.len(), 2);
        assert!(reg.single().is_none(), "two models — no implicit default");

        // a's file changes (different arch → different size) → new Arc
        write_model(&dir, "a", vec![3, 7, 2], 9);
        let rep = reg.reload();
        assert_eq!(rep.loaded, vec!["a".to_string()]);
        let a_v2 = reg.get("a").unwrap();
        assert!(!Arc::ptr_eq(&a_v1, &a_v2));
        assert_eq!(a_v2.arch, vec![3, 7, 2]);

        // removal drops the model
        std::fs::remove_file(dir.join("b.dmdp")).unwrap();
        let rep = reg.reload();
        assert_eq!(rep.dropped, vec!["b".to_string()]);
        assert!(reg.get("b").is_none());
    }

    #[test]
    fn torn_or_corrupt_reload_keeps_previous_model() {
        let dir = temp_dir("torn");
        write_model(&dir, "m", vec![3, 4, 2], 5);
        let (reg, report) = ModelRegistry::open(&dir);
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        let v1 = reg.get("m").unwrap();
        let path = dir.join("m.dmdp");
        let good = std::fs::read(&path).unwrap();

        // torn file: a crash mid-write leaves a truncated checkpoint
        std::fs::write(&path, &good[..good.len() / 2]).unwrap();
        let rep = reg.reload();
        assert_eq!(rep.errors.len(), 1, "{:?}", rep.errors);
        assert_eq!(rep.errors[0].0, "m");
        assert!(rep.loaded.is_empty() && rep.dropped.is_empty());
        assert!(
            Arc::ptr_eq(&v1, &reg.get("m").unwrap()),
            "previous model must keep serving past a torn file"
        );

        // bit rot: full-length file failing the CRC trailer
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0xff;
        std::fs::write(&path, &bad).unwrap();
        let rep = reg.reload();
        assert_eq!(rep.errors.len(), 1, "{:?}", rep.errors);
        assert!(
            rep.errors[0].1.contains("checksum") || rep.errors[0].1.contains("implausible"),
            "unexpected error: {}",
            rep.errors[0].1
        );
        assert!(Arc::ptr_eq(&v1, &reg.get("m").unwrap()));

        // a repaired file loads again, into a fresh Arc
        std::fs::write(&path, &good).unwrap();
        let rep = reg.reload();
        assert_eq!(rep.loaded, vec!["m".to_string()], "{:?}", rep.errors);
        assert!(!Arc::ptr_eq(&v1, &reg.get("m").unwrap()));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn write_sidecar_roundtrips_through_load() {
        let dir = temp_dir("sidecar_rt");
        write_model(&dir, "m", vec![3, 5, 2], 8);
        let scaling = Scaling {
            in_ranges: vec![(0.1, 19.7), (-0.25, 0.25), (1.0e-3, 2.5)],
            out_range: (0.0, 123.456),
        };
        write_sidecar(dir.join("m.dmdp"), &[3, 5, 2], Some(&scaling), Some("rom")).unwrap();
        let (reg, report) = ModelRegistry::open(&dir);
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        let model = reg.get("m").unwrap();
        let loaded = model.scaling.as_ref().unwrap();
        // exact f32 bounds survive the JSON round-trip
        assert_eq!(loaded.in_ranges, scaling.in_ranges);
        assert_eq!(loaded.out_range, scaling.out_range);
        assert_eq!(model.workload.as_deref(), Some("rom"));
    }

    #[test]
    fn sidecar_without_workload_loads_as_untagged() {
        let dir = temp_dir("no_workload");
        write_model(&dir, "m", vec![3, 4, 2], 2);
        std::fs::write(dir.join("m.json"), "{\"arch\": [3, 4, 2]}\n").unwrap();
        let (reg, report) = ModelRegistry::open(&dir);
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        assert_eq!(reg.get("m").unwrap().workload, None);
    }

    #[test]
    fn missing_dir_is_empty_not_error() {
        let dir = std::env::temp_dir().join("dmdtrain_registry_never_created");
        let _ = std::fs::remove_dir_all(&dir);
        let (reg, report) = ModelRegistry::open(&dir);
        assert!(reg.is_empty());
        assert!(!report.changed());
        assert!(report.errors.is_empty());
    }
}
