//! Per-model circuit breaker: a model that keeps panicking in predict
//! or failing to reload is quarantined so it stops burning dispatcher
//! time (and stops taking the respawn budget down with it), while every
//! other model in the registry keeps serving.
//!
//! Classic three-state machine, tracked independently per model name:
//!
//! * **Closed** — healthy; failures accumulate strikes, any success
//!   clears them.
//! * **Open** — quarantined after [`BREAKER_THRESHOLD`] consecutive
//!   strikes; predicts are refused (404 + reason) until the cooldown
//!   elapses.
//! * **Half-open** — after the cooldown exactly one probe request is
//!   admitted; success closes the breaker, failure re-opens it for
//!   another full cooldown.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Consecutive failures (predict panic, predict error, reload error)
/// before a model's breaker opens.
pub const BREAKER_THRESHOLD: u32 = 3;

/// How long an open breaker refuses traffic before admitting one
/// half-open probe.
pub const BREAKER_COOLDOWN: Duration = Duration::from_secs(5);

/// What the breaker says about admitting a request for a model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Closed (or half-open probe slot granted) — serve it.
    Allow,
    /// Open — refuse with the remaining cooldown as the back-off hint.
    Quarantined { retry_in: Duration },
}

#[derive(Clone, Copy, Debug)]
enum State {
    Closed { strikes: u32 },
    Open { until: Instant },
    /// One probe is in flight; further requests stay refused until it
    /// resolves (success → Closed, failure → Open).
    HalfOpen,
}

#[derive(Debug)]
pub struct CircuitBreaker {
    threshold: u32,
    cooldown: Duration,
    states: Mutex<HashMap<String, State>>,
}

impl Default for CircuitBreaker {
    fn default() -> Self {
        Self::new()
    }
}

impl CircuitBreaker {
    pub fn new() -> CircuitBreaker {
        CircuitBreaker::with(BREAKER_THRESHOLD, BREAKER_COOLDOWN)
    }

    /// Custom threshold/cooldown (tests shrink the cooldown to keep the
    /// half-open path fast).
    pub fn with(threshold: u32, cooldown: Duration) -> CircuitBreaker {
        CircuitBreaker {
            threshold: threshold.max(1),
            cooldown,
            states: Mutex::new(HashMap::new()),
        }
    }

    /// Admission decision for one request. An expired open breaker
    /// transitions to half-open here and admits the caller as the probe.
    pub fn check(&self, model: &str) -> Admission {
        let mut states = self.states.lock().unwrap_or_else(|e| e.into_inner());
        match states.get(model).copied() {
            None | Some(State::Closed { .. }) => Admission::Allow,
            Some(State::Open { until }) => {
                let now = Instant::now();
                if now >= until {
                    states.insert(model.to_string(), State::HalfOpen);
                    Admission::Allow
                } else {
                    Admission::Quarantined {
                        retry_in: until - now,
                    }
                }
            }
            // probe already in flight — don't stampede a sick model
            Some(State::HalfOpen) => Admission::Quarantined {
                retry_in: self.cooldown,
            },
        }
    }

    /// A predict (or reload) succeeded: clear strikes / close a
    /// half-open breaker.
    pub fn record_success(&self, model: &str) {
        self.states
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .remove(model);
    }

    /// A predict panicked/errored or a reload failed. Returns `true`
    /// when this strike opened (or re-opened) the breaker — callers
    /// count that edge in `dmdtrain_breaker_opens_total`.
    pub fn record_failure(&self, model: &str) -> bool {
        let mut states = self.states.lock().unwrap_or_else(|e| e.into_inner());
        let state = states
            .entry(model.to_string())
            .or_insert(State::Closed { strikes: 0 });
        match *state {
            State::Closed { strikes } => {
                let strikes = strikes + 1;
                if strikes >= self.threshold {
                    *state = State::Open {
                        until: Instant::now() + self.cooldown,
                    };
                    true
                } else {
                    *state = State::Closed { strikes };
                    false
                }
            }
            // failed probe: straight back to a full cooldown
            State::HalfOpen => {
                *state = State::Open {
                    until: Instant::now() + self.cooldown,
                };
                true
            }
            // already open (e.g. reload failures while quarantined) —
            // keep the existing deadline so retries stay predictable
            State::Open { .. } => false,
        }
    }

    /// Names with an open or half-open breaker (for `/readyz` detail).
    pub fn quarantined(&self) -> Vec<String> {
        let states = self.states.lock().unwrap_or_else(|e| e.into_inner());
        let mut names: Vec<String> = states
            .iter()
            .filter(|(_, s)| !matches!(s, State::Closed { .. }))
            .map(|(n, _)| n.clone())
            .collect();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opens_after_threshold_consecutive_failures() {
        let b = CircuitBreaker::with(3, Duration::from_secs(60));
        assert_eq!(b.check("m"), Admission::Allow);
        assert!(!b.record_failure("m"));
        assert!(!b.record_failure("m"));
        assert_eq!(b.check("m"), Admission::Allow, "below threshold");
        assert!(b.record_failure("m"), "third strike opens");
        match b.check("m") {
            Admission::Quarantined { retry_in } => assert!(retry_in <= Duration::from_secs(60)),
            Admission::Allow => panic!("open breaker admitted a request"),
        }
        assert_eq!(b.quarantined(), vec!["m".to_string()]);
        // other models are untouched
        assert_eq!(b.check("other"), Admission::Allow);
    }

    #[test]
    fn success_resets_the_strike_count() {
        let b = CircuitBreaker::with(3, Duration::from_secs(60));
        b.record_failure("m");
        b.record_failure("m");
        b.record_success("m");
        b.record_failure("m");
        b.record_failure("m");
        assert_eq!(b.check("m"), Admission::Allow, "streak was broken");
    }

    #[test]
    fn half_open_admits_one_probe_then_closes_on_success() {
        let b = CircuitBreaker::with(1, Duration::from_millis(20));
        assert!(b.record_failure("m"));
        assert!(matches!(b.check("m"), Admission::Quarantined { .. }));
        std::thread::sleep(Duration::from_millis(30));
        // cooldown elapsed: first check is the probe, second is refused
        assert_eq!(b.check("m"), Admission::Allow);
        assert!(matches!(b.check("m"), Admission::Quarantined { .. }));
        b.record_success("m");
        assert_eq!(b.check("m"), Admission::Allow);
        assert!(b.quarantined().is_empty());
    }

    #[test]
    fn failed_probe_reopens_for_a_full_cooldown() {
        let b = CircuitBreaker::with(1, Duration::from_millis(20));
        assert!(b.record_failure("m"));
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(b.check("m"), Admission::Allow, "probe admitted");
        assert!(b.record_failure("m"), "failed probe re-opens");
        assert!(matches!(b.check("m"), Admission::Quarantined { .. }));
    }

    #[test]
    fn failures_while_open_do_not_extend_the_deadline() {
        let b = CircuitBreaker::with(1, Duration::from_millis(30));
        assert!(b.record_failure("m"));
        // reload failures keep arriving while quarantined
        assert!(!b.record_failure("m"));
        assert!(!b.record_failure("m"));
        std::thread::sleep(Duration::from_millis(40));
        assert_eq!(b.check("m"), Admission::Allow, "original deadline held");
    }
}
