//! Request routing for the inference server.
//!
//! Routes:
//! * `GET /healthz`  — liveness + loaded-model count (always 200 while
//!   the process serves)
//! * `GET /readyz`   — readiness state machine: `ready`, `degraded`
//!   (reload backoff streak, batcher restarts, brownout, or quarantined
//!   models — still 200), or `draining` (503; graceful stop underway)
//! * `GET /models`   — registry listing (name, arch, params, scaling, workload)
//! * `GET /metrics`  — Prometheus text exposition
//! * `POST /reload`  — rescan the model directory now
//! * `POST /predict` — JSON predict, coalesced by the micro-batcher
//!
//! Shed classification: **429** means *the server* refused to queue the
//! request (full queue after the bounded submit wait, or the model's
//! per-model concurrency budget) — retry after the computed
//! `Retry-After`. **503** means an accepted request could not be
//! answered (deadline expired in queue, dispatcher down/draining).
//! **404 + reason** means the model's circuit breaker is open.
//!
//! `POST /predict` body: `{"model": "name", "inputs": [[…], …]}` —
//! `inputs` is a list of rows (or one flat row), `model` may be omitted
//! when exactly one model is loaded. Response:
//! `{"model": "name", "rows": N, "outputs": [[…], …]}`.
//!
//! Float fidelity: outputs are formatted with Rust's shortest-roundtrip
//! `Display`, so every serialized value parses back to the exact f64 of
//! the computed f32 — served predictions are bit-identical to calling
//! `Executable::predict` directly on the same checkpoint (the standing
//! invariant in `tests/serve_integration.rs`).

use super::admission::InflightBudget;
use super::batcher::{BatcherHandle, PredictFail, PredictJob, SubmitError};
use super::breaker::{Admission, CircuitBreaker};
use super::http::{Request, Response};
use super::registry::{ModelRegistry, ServedModel};
use crate::metrics::serve::ServeMetrics;
use crate::tensor::Tensor;
use crate::util::jsonl::{parse, Json};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Rows per single request (the batcher caps per-GEMM rows separately).
pub const MAX_REQUEST_ROWS: usize = 65_536;

/// Shared server state handed to every connection thread.
pub struct AppState {
    pub registry: Arc<ModelRegistry>,
    pub metrics: Arc<ServeMetrics>,
    pub started: Instant,
    /// Graceful stop underway: `/readyz` answers `draining` (503) and
    /// keep-alive is downgraded so handlers exit after their current
    /// request.
    pub draining: Arc<AtomicBool>,
    /// Current background reload-failure streak (0 = healthy); nonzero
    /// degrades `/readyz`.
    pub reload_streak: Arc<AtomicU32>,
    /// Per-model quarantine after repeated predict/reload failures.
    pub breaker: Arc<CircuitBreaker>,
    /// Per-model in-flight caps (`serve.per_model_inflight`).
    pub budget: Arc<InflightBudget>,
    /// Server-side predict deadline (`serve.request_timeout_ms`);
    /// `None` = header-only deadlines.
    pub request_timeout: Option<Duration>,
}

/// Dispatch one request; never panics — all failures map to 4xx/5xx.
pub fn handle(state: &AppState, batcher: &BatcherHandle, req: &Request) -> Response {
    state.metrics.http_requests.inc();
    let resp = match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => healthz(state),
        ("GET", "/readyz") => readyz(state, batcher),
        ("GET", "/models") => models(state),
        ("GET", "/metrics") => metrics_page(state),
        ("POST", "/reload") => reload(state),
        ("POST", "/predict") => predict(state, batcher, req),
        ("GET", "/predict") | ("GET", "/reload") => {
            Response::error(405, "use POST for this endpoint")
        }
        _ => Response::error(404, &format!("no route {} {}", req.method, req.path)),
    };
    if resp.status >= 400 {
        state.metrics.http_errors.inc();
    }
    resp
}

/// Prometheus exposition: the serve-side families plus the process-wide
/// trainer registry — a server embedded in a training process (or one
/// that trained models in-process) exposes both on one page.
fn metrics_page(state: &AppState) -> Response {
    let mut body = state.metrics.render_prometheus();
    body.push_str(&crate::metrics::core::TrainMetrics::global().render_prometheus());
    Response::text(200, body)
}

fn healthz(state: &AppState) -> Response {
    let body = format!(
        "{{\"status\":\"ok\",\"models\":{},\"uptime_secs\":{}}}",
        state.registry.len(),
        state.started.elapsed().as_secs()
    );
    Response::json(200, body)
}

/// Readiness state machine. `draining` is 503 so load balancers pull
/// the instance; `degraded` stays 200 (still serving, but something is
/// limping) with the reasons listed.
fn readyz(state: &AppState, batcher: &BatcherHandle) -> Response {
    let pressure = batcher.pressure();
    if state.draining.load(Ordering::Relaxed) {
        return Response::json(
            503,
            format!(
                "{{\"state\":\"draining\",\"queue_depth\":{}}}",
                pressure.depth()
            ),
        );
    }
    let mut reasons: Vec<String> = Vec::new();
    let streak = state.reload_streak.load(Ordering::Relaxed);
    if streak > 0 {
        reasons.push(format!("reload_backoff_streak={streak}"));
    }
    let restarts = state.metrics.batcher_restarts.get();
    if restarts > 0 {
        reasons.push(format!("batcher_restarts={restarts}"));
    }
    if pressure.in_brownout() {
        reasons.push("brownout".to_string());
    }
    let quarantined = state.breaker.quarantined();
    if !quarantined.is_empty() {
        reasons.push(format!("quarantined_models={}", quarantined.len()));
    }
    let ready_state = if reasons.is_empty() { "ready" } else { "degraded" };
    let reasons_json: Vec<String> = reasons
        .into_iter()
        .map(|r| Json::Str(r).encode())
        .collect();
    let body = format!(
        "{{\"state\":\"{ready_state}\",\"reasons\":[{}],\"models\":{},\"queue_depth\":{}}}",
        reasons_json.join(","),
        state.registry.len(),
        pressure.depth()
    );
    Response::json(200, body)
}

fn models(state: &AppState) -> Response {
    let mut body = String::from("{\"models\":[");
    for (i, m) in state.registry.list().iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        let _ = write!(
            body,
            "{{\"name\":{},\"arch\":{:?},\"param_count\":{},\"scaled\":{}}}",
            Json::Str(m.name.clone()).encode(),
            m.arch,
            m.param_count(),
            m.scaling.is_some()
        );
        // additive: only checkpoints with a workload-tagged sidecar
        // carry the key, so pre-workload clients see unchanged rows
        if let Some(w) = &m.workload {
            body.pop();
            let _ = write!(body, ",\"workload\":{}}}", Json::Str(w.clone()).encode());
        }
    }
    body.push_str("]}");
    Response::json(200, body)
}

fn reload(state: &AppState) -> Response {
    let report = state.registry.reload();
    state.metrics.registry_reloads.inc();
    super::note_reload_outcome(&state.breaker, &state.metrics, &report);
    let names = |v: &[String]| -> String {
        let quoted: Vec<String> = v.iter().map(|s| Json::Str(s.clone()).encode()).collect();
        format!("[{}]", quoted.join(","))
    };
    let errs: Vec<String> = report
        .errors
        .iter()
        .map(|(n, e)| {
            format!(
                "{{\"model\":{},\"error\":{}}}",
                Json::Str(n.clone()).encode(),
                Json::Str(e.clone()).encode()
            )
        })
        .collect();
    let body = format!(
        "{{\"loaded\":{},\"dropped\":{},\"errors\":[{}]}}",
        names(&report.loaded),
        names(&report.dropped),
        errs.join(",")
    );
    Response::json(200, body)
}

fn predict(state: &AppState, batcher: &BatcherHandle, req: &Request) -> Response {
    let t0 = Instant::now();
    let (model, x) = match parse_predict_body(state, &req.body) {
        Ok(ok) => ok,
        Err(resp) => return resp,
    };

    // circuit breaker: a quarantined model is refused outright so a
    // sick checkpoint cannot keep eating dispatcher time
    if let Admission::Quarantined { retry_in } = state.breaker.check(&model.name) {
        state.metrics.breaker_rejects.inc();
        let secs = retry_in.as_secs().max(1);
        return Response::error(
            404,
            &format!(
                "model '{}' is quarantined after repeated failures; retry in ~{secs}s",
                model.name
            ),
        )
        .with_retry_after(secs);
    }

    // per-model concurrency budget: one hot model saturating its slots
    // sheds its own traffic instead of starving every other model
    let budget = match state.budget.try_acquire(&model.name) {
        Some(g) => g,
        None => {
            state.metrics.budget_shed.inc();
            return Response::error(
                429,
                &format!("model '{}' is at its concurrency budget, retry later", model.name),
            )
            .with_retry_after(batcher.retry_after_hint());
        }
    };

    state.metrics.predict_requests.inc();
    state.metrics.predict_rows.add(x.rows() as u64);

    // effective deadline: the tighter of the server budget and the
    // client's X-Deadline-Ms header
    let timeout = match (state.request_timeout, req.deadline_ms) {
        (Some(s), Some(h)) => Some(s.min(Duration::from_millis(h))),
        (Some(s), None) => Some(s),
        (None, Some(h)) => Some(Duration::from_millis(h)),
        (None, None) => None,
    };
    let deadline = timeout.map(|t| t0 + t);

    let (reply_tx, reply_rx) = sync_channel(1);
    let job = PredictJob::new(Arc::clone(&model), x, reply_tx)
        .with_deadline(deadline)
        .with_budget(Some(budget));
    match batcher.submit(job) {
        Ok(()) => {}
        Err(SubmitError::Overloaded) => {
            // load shed: bounded-wait submit gave up on a full queue —
            // tell the client to back off instead of queueing forever;
            // the hint is computed from queue depth over drain rate
            state.metrics.predict_shed.inc();
            return Response::error(429, "predict queue is full, retry later")
                .with_retry_after(batcher.retry_after_hint());
        }
        Err(SubmitError::Down) => {
            return Response::error(503, "predict dispatcher is down");
        }
    }
    let result = match reply_rx.recv() {
        Ok(r) => r,
        Err(_) => return Response::error(503, "predict dispatcher dropped the request"),
    };
    let y = match result {
        Ok(y) => y,
        Err(fail @ PredictFail::Deadline { .. }) => {
            return Response::error(503, &fail.to_string());
        }
        Err(PredictFail::Panicked) => {
            return Response::error(
                500,
                &format!("predict failed: model '{}' panicked", model.name),
            );
        }
        Err(PredictFail::Failed(msg)) => {
            return Response::error(500, &format!("predict failed: {msg}"));
        }
    };
    state.metrics.predict_latency.observe(t0.elapsed().as_secs_f64());

    let mut body = String::with_capacity(y.len() * 12 + 64);
    let _ = write!(
        body,
        "{{\"model\":{},\"rows\":{},\"outputs\":[",
        Json::Str(model.name.clone()).encode(),
        y.rows()
    );
    for r in 0..y.rows() {
        if r > 0 {
            body.push(',');
        }
        body.push('[');
        for (c, &v) in y.row(r).iter().enumerate() {
            if c > 0 {
                body.push(',');
            }
            // shortest-roundtrip Display keeps the exact f32 bits
            // (including -0.0, which the Json::Num encoder would lose)
            if v.is_finite() {
                let _ = write!(body, "{}", v as f64);
            } else {
                body.push_str("null");
            }
        }
        body.push(']');
    }
    body.push_str("]}");
    Response::json(200, body)
}

/// Parse + validate a predict body; errors come back as ready responses.
fn parse_predict_body(
    state: &AppState,
    body: &[u8],
) -> Result<(Arc<ServedModel>, Tensor), Response> {
    let text = std::str::from_utf8(body)
        .map_err(|_| Response::error(400, "body is not valid UTF-8"))?;
    let doc = parse(text).map_err(|e| Response::error(400, &format!("bad JSON: {e}")))?;

    let model = match doc.get("model").and_then(Json::as_str) {
        Some(name) => state
            .registry
            .get(name)
            .ok_or_else(|| Response::error(404, &format!("model '{name}' not loaded")))?,
        None => state.registry.single().ok_or_else(|| {
            if state.registry.is_empty() {
                Response::error(404, "no models loaded")
            } else {
                Response::error(400, "several models loaded — specify \"model\"")
            }
        })?,
    };

    let inputs = doc
        .get("inputs")
        .and_then(Json::as_arr)
        .ok_or_else(|| Response::error(400, "missing \"inputs\" array"))?;
    if inputs.is_empty() {
        return Err(Response::error(400, "\"inputs\" is empty"));
    }

    let n_in = model.n_in();
    // one flat row, or a list of rows
    let rows: Vec<Vec<f64>> = if inputs[0].as_f64().is_some() {
        vec![numbers(inputs).map_err(|e| Response::error(400, &e))?]
    } else {
        let mut out = Vec::with_capacity(inputs.len());
        for (i, row) in inputs.iter().enumerate() {
            let row = row
                .as_arr()
                .ok_or_else(|| Response::error(400, &format!("inputs[{i}] is not an array")))?;
            out.push(numbers(row).map_err(|e| Response::error(400, &e))?);
        }
        out
    };
    if rows.len() > MAX_REQUEST_ROWS {
        return Err(Response::error(
            400,
            &format!("{} rows exceeds the per-request cap {MAX_REQUEST_ROWS}", rows.len()),
        ));
    }
    for (i, row) in rows.iter().enumerate() {
        if row.len() != n_in {
            return Err(Response::error(
                400,
                &format!(
                    "inputs[{i}] has {} features, model '{}' expects {n_in}",
                    row.len(),
                    model.name
                ),
            ));
        }
    }

    let mut x = Tensor::zeros(rows.len(), n_in);
    for (r, row) in rows.iter().enumerate() {
        for (c, &v) in row.iter().enumerate() {
            x.set(r, c, v as f32);
        }
    }
    Ok((model, x))
}

fn numbers(arr: &[Json]) -> Result<Vec<f64>, String> {
    arr.iter()
        .map(|v| v.as_f64().ok_or_else(|| "non-numeric input value".to_string()))
        .collect()
}
