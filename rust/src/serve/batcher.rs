//! Micro-batching predict dispatcher: concurrent `POST /predict`
//! requests landing within one batch window are coalesced into a single
//! GEMM over the shared [`crate::util::pool::WorkerPool`], so
//! per-request cost amortizes exactly like training batches do.
//!
//! One dispatcher thread owns the queue: it takes the oldest pending
//! job, keeps the window open for up to `window` (or until `max_rows`
//! rows accumulate), stacks every same-model job's rows into one input
//! tensor, runs one `predict`, and splits the output rows back to the
//! per-request reply channels. Jobs for a *different* model arriving
//! inside the window are carried over and dispatched next round.
//!
//! Overload behavior: jobs carry an optional deadline and are shed with
//! [`PredictFail::Deadline`] the moment they expire — when popped as
//! head, when received inside the window, and in a final sweep right
//! before the GEMM — so an overloaded dispatcher never spends kernel
//! time on an answer nobody is waiting for. Sustained pressure (queue
//! ≥ 3/4 full) enters a brownout that shrinks the batch window by
//! [`BROWNOUT_WINDOW_DIV`] until the queue drains below 1/4. Predict
//! panics are caught per dispatch and counted against the model's
//! [`CircuitBreaker`] instead of killing the dispatcher.
//!
//! Determinism: the native predict GEMM accumulates every output element
//! in a fixed per-row order independent of the other rows in the batch
//! (see `linalg::gemm`), and scaling is elementwise — so a micro-batched
//! response is bit-identical to the same request served alone, whatever
//! the coalescing, thread count, or batch composition.

use super::admission::{InflightGuard, QueuePressure};
use super::breaker::CircuitBreaker;
use super::registry::ServedModel;
use crate::metrics::serve::ServeMetrics;
use crate::tensor::Tensor;
use crate::util::failpoint;
use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Default queue depth before submits start waiting (backpressure);
/// `serve.max_queue_jobs` overrides it.
pub const DEFAULT_QUEUE_DEPTH: usize = 1024;

/// Default bounded submit wait on a full queue (`serve.submit_wait_ms`
/// overrides it). Bounded so a wedged dispatcher turns into load
/// shedding (HTTP 429 at the router), never an indefinitely blocked
/// connection thread.
pub const DEFAULT_SUBMIT_WAIT: Duration = Duration::from_millis(50);

/// Window divisor while the dispatcher is in brownout: a shorter window
/// trades batching efficiency for queue drain when under sustained
/// pressure.
pub const BROWNOUT_WINDOW_DIV: u32 = 4;

/// How long the `serve.queue.stall` failpoint wedges the dispatcher per
/// loop iteration while armed.
const STALL_PAUSE: Duration = Duration::from_millis(25);

/// Dispatcher drain-rate EWMA refresh cadence.
const RATE_REFRESH: Duration = Duration::from_millis(200);

/// Dispatcher respawns allowed after panics before the batcher goes
/// permanently down (submits answer `Down`, the router 503s). Bounded so
/// a deterministic panic (poisoned model state, corrupt job) cannot spin
/// the respawn loop forever; each respawn increments
/// `dmdtrain_batcher_restarts_total`. Predict panics are caught per
/// dispatch and do *not* consume this budget.
pub const MAX_DISPATCHER_RESTARTS: u64 = 3;

/// Why a submit was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The queue stayed full for the whole bounded wait — the request
    /// is shed (the router answers 429 + `Retry-After`).
    Overloaded,
    /// The dispatcher thread is gone (shutdown or crash) — 503.
    Down,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "predict queue is full"),
            SubmitError::Down => write!(f, "predict dispatcher is down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Why an accepted job came back without a prediction.
#[derive(Clone, Debug)]
pub enum PredictFail {
    /// The deadline expired while the job was queued — shed before the
    /// GEMM (the router answers 503 + `deadline exceeded`).
    Deadline { waited: Duration },
    /// The predict call panicked (500; counts a breaker strike).
    Panicked,
    /// The predict call returned an error (500; counts a breaker
    /// strike).
    Failed(String),
}

impl std::fmt::Display for PredictFail {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PredictFail::Deadline { waited } => {
                write!(f, "deadline exceeded after {} ms in queue", waited.as_millis())
            }
            PredictFail::Panicked => write!(f, "predict panicked"),
            PredictFail::Failed(msg) => write!(f, "{msg}"),
        }
    }
}

/// One predict request in flight.
pub struct PredictJob {
    pub model: Arc<ServedModel>,
    /// (rows, n_in) input tensor — shape pre-validated by the router.
    pub inputs: Tensor,
    /// Receives the (rows, n_out) result or the shed/failure reason.
    pub reply: SyncSender<Result<Tensor, PredictFail>>,
    /// When the job entered the queue (feeds the queue-wait histogram).
    pub enqueued: Instant,
    /// Shed the job unanswered-by-GEMM once this passes (request
    /// timeout / `X-Deadline-Ms`).
    pub deadline: Option<Instant>,
    /// Per-model concurrency slot, released when the job is answered.
    pub budget: Option<InflightGuard>,
}

impl PredictJob {
    pub fn new(
        model: Arc<ServedModel>,
        inputs: Tensor,
        reply: SyncSender<Result<Tensor, PredictFail>>,
    ) -> PredictJob {
        PredictJob {
            model,
            inputs,
            reply,
            enqueued: Instant::now(),
            deadline: None,
            budget: None,
        }
    }

    pub fn with_deadline(mut self, deadline: Option<Instant>) -> PredictJob {
        self.deadline = deadline;
        self
    }

    pub fn with_budget(mut self, budget: Option<InflightGuard>) -> PredictJob {
        self.budget = budget;
        self
    }

    fn expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }
}

enum Msg {
    Job(PredictJob),
    Shutdown,
}

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// How long the dispatcher keeps a batch open for more rows.
    /// `Duration::ZERO` disables coalescing (every request runs alone).
    pub window: Duration,
    /// Row cap per dispatched GEMM.
    pub max_rows: usize,
    /// Queue bound (`serve.max_queue_jobs`): submits past this start
    /// the bounded wait, then shed with 429.
    pub max_queue: usize,
    /// Longest a submit waits on a full queue before shedding
    /// (`serve.submit_wait_ms`).
    pub submit_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            window: Duration::from_millis(1),
            max_rows: 256,
            max_queue: DEFAULT_QUEUE_DEPTH,
            submit_wait: DEFAULT_SUBMIT_WAIT,
        }
    }
}

/// Handle used by request threads to submit jobs. Each connection
/// thread owns its clone, so the `SyncSender` is never shared by
/// reference across threads.
pub struct BatcherHandle {
    tx: SyncSender<Msg>,
    submit_wait: Duration,
    pressure: Arc<QueuePressure>,
}

impl Clone for BatcherHandle {
    fn clone(&self) -> Self {
        BatcherHandle {
            tx: self.tx.clone(),
            submit_wait: self.submit_wait,
            pressure: Arc::clone(&self.pressure),
        }
    }
}

impl BatcherHandle {
    /// Enqueue a job. Waits at most the configured submit wait when the
    /// queue is full, then sheds with [`SubmitError::Overloaded`] —
    /// submit never blocks a connection thread indefinitely.
    pub fn submit(&self, job: PredictJob) -> Result<(), SubmitError> {
        // failpoint: `serve.batcher.full` simulates a saturated queue
        if failpoint::fire("serve.batcher.full").is_some() {
            return Err(SubmitError::Overloaded);
        }
        let mut msg = Msg::Job(job);
        let deadline = Instant::now() + self.submit_wait;
        loop {
            match self.tx.try_send(msg) {
                Ok(()) => {
                    self.pressure.enqueued();
                    return Ok(());
                }
                Err(TrySendError::Disconnected(_)) => return Err(SubmitError::Down),
                Err(TrySendError::Full(m)) => {
                    if Instant::now() >= deadline {
                        return Err(SubmitError::Overloaded);
                    }
                    msg = m;
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
    }

    /// Live queue state (depth, drain rate, brownout flag).
    pub fn pressure(&self) -> &Arc<QueuePressure> {
        &self.pressure
    }

    /// `Retry-After` hint computed from observed queue depth and drain
    /// rate (clamped to [1, 30] s).
    pub fn retry_after_hint(&self) -> u64 {
        self.pressure.retry_after_hint()
    }
}

/// The dispatcher thread plus its submit side. Dropping the `Batcher`
/// sends a shutdown sentinel and joins the thread (pending jobs are
/// still answered).
pub struct Batcher {
    tx: SyncSender<Msg>,
    pressure: Arc<QueuePressure>,
    submit_wait: Duration,
    thread: Option<JoinHandle<()>>,
}

impl Batcher {
    pub fn start(
        cfg: BatcherConfig,
        metrics: Arc<ServeMetrics>,
        breaker: Arc<CircuitBreaker>,
    ) -> Batcher {
        let (tx, rx) = sync_channel::<Msg>(cfg.max_queue.max(1));
        let pressure = Arc::new(QueuePressure::new());
        let thread = {
            let pressure = Arc::clone(&pressure);
            std::thread::Builder::new()
                .name("dmdtrain-batcher".to_string())
                .spawn(move || {
                    // Self-healing: a panicked dispatch loop is respawned up
                    // to MAX_DISPATCHER_RESTARTS times. The queue survives a
                    // respawn — `rx` lives here, outside the loop — so jobs
                    // submitted around the panic are still answered. Past the
                    // cap the batcher goes permanently down (submits answer
                    // `Down`, the router 503s).
                    let mut restarts: u64 = 0;
                    loop {
                        match std::panic::catch_unwind(AssertUnwindSafe(|| {
                            run(&rx, cfg, &metrics, &pressure, &breaker)
                        })) {
                            Ok(()) => break,
                            Err(_) if restarts < MAX_DISPATCHER_RESTARTS => {
                                restarts += 1;
                                metrics.batcher_restarts.inc();
                                eprintln!(
                                    "serve: predict dispatcher panicked; respawning \
                                     ({restarts}/{MAX_DISPATCHER_RESTARTS})"
                                );
                            }
                            Err(_) => {
                                eprintln!(
                                    "serve: predict dispatcher panicked {} times; \
                                     restart budget exhausted, batcher is down",
                                    restarts + 1
                                );
                                break;
                            }
                        }
                    }
                })
                .expect("spawn batcher thread")
        };
        Batcher {
            tx,
            pressure,
            submit_wait: cfg.submit_wait,
            thread: Some(thread),
        }
    }

    pub fn handle(&self) -> BatcherHandle {
        BatcherHandle {
            tx: self.tx.clone(),
            submit_wait: self.submit_wait,
            pressure: Arc::clone(&self.pressure),
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Brownout hysteresis: enter when the queue is ≥ 3/4 full, leave when
/// it drains to ≤ 1/4. The wide gap keeps the window from flapping at
/// one threshold under steady load.
struct Brownout {
    on: bool,
    max_queue: usize,
}

impl Brownout {
    fn new(max_queue: usize) -> Brownout {
        Brownout {
            on: false,
            max_queue: max_queue.max(1),
        }
    }

    /// Digest one depth observation; `Some(entered)` on a transition.
    fn observe(&mut self, depth: usize) -> Option<bool> {
        if !self.on && depth * 4 >= self.max_queue * 3 {
            self.on = true;
            Some(true)
        } else if self.on && depth * 4 <= self.max_queue {
            self.on = false;
            Some(false)
        } else {
            None
        }
    }
}

/// Dispatcher-side drain-rate EWMA refresh (smooths the
/// depth-over-rate `Retry-After` estimate).
struct RateTracker {
    last: Instant,
    drained_then: u64,
}

impl RateTracker {
    fn new(pressure: &QueuePressure) -> RateTracker {
        RateTracker {
            last: Instant::now(),
            drained_then: pressure.drained(),
        }
    }

    fn tick(&mut self, pressure: &QueuePressure) {
        let dt = self.last.elapsed();
        if dt < RATE_REFRESH {
            return;
        }
        let drained = pressure.drained();
        let inst = (drained - self.drained_then) as f64 / dt.as_secs_f64();
        let prev = pressure.drain_rate();
        let ewma = if prev > 0.0 { 0.7 * prev + 0.3 * inst } else { inst };
        pressure.set_drain_rate(ewma);
        self.last = Instant::now();
        self.drained_then = drained;
    }
}

fn run(
    rx: &Receiver<Msg>,
    cfg: BatcherConfig,
    metrics: &ServeMetrics,
    pressure: &QueuePressure,
    breaker: &CircuitBreaker,
) {
    let max_rows = cfg.max_rows.max(1);
    let mut carry: VecDeque<PredictJob> = VecDeque::new();
    let mut brownout = Brownout::new(cfg.max_queue);
    let mut rate = RateTracker::new(pressure);
    'outer: loop {
        // failpoint: `serve.batcher.panic` kills the dispatch loop. The
        // supervisor in `Batcher::start` respawns it up to
        // MAX_DISPATCHER_RESTARTS times; a persistent panic burns the
        // budget and submits then fail with `Down` — the router answers
        // 503 instead of hanging (asserted in tests/fault_injection.rs)
        failpoint::panic_point("serve.batcher.panic");
        // failpoint: `serve.queue.stall` wedges the dispatcher for a
        // beat per loop iteration, so armed persistently the queue
        // backs up and deadlines expire (chaos soak / fault tests)
        if failpoint::fire("serve.queue.stall").is_some() {
            std::thread::sleep(STALL_PAUSE);
        }
        // Head job: oldest carried-over job, else block for the next
        // one. Jobs already past their deadline are shed right here —
        // no window, no GEMM.
        let head = loop {
            let job = match carry.pop_front() {
                Some(j) => j,
                None => match rx.recv() {
                    Ok(Msg::Job(j)) => j,
                    Ok(Msg::Shutdown) | Err(_) => break 'outer,
                },
            };
            if job.expired() {
                shed_expired(job, metrics, pressure);
                continue;
            }
            break job;
        };
        let window = match brownout.observe(pressure.depth()) {
            Some(true) => {
                pressure.set_brownout(true);
                metrics.batcher_brownouts.inc();
                eprintln!(
                    "serve: predict queue at {}/{} — brownout, batch window shrunk \
                     /{BROWNOUT_WINDOW_DIV}",
                    pressure.depth(),
                    cfg.max_queue
                );
                cfg.window / BROWNOUT_WINDOW_DIV
            }
            Some(false) => {
                pressure.set_brownout(false);
                eprintln!("serve: predict queue drained — brownout over");
                cfg.window
            }
            None if brownout.on => cfg.window / BROWNOUT_WINDOW_DIV,
            None => cfg.window,
        };
        // span covers the open window plus the coalesced dispatch;
        // arg carries the final row count of the batch
        let mut window_span = crate::obs::span("batch_window");
        let mut rows = head.inputs.rows();
        let mut batch = vec![head];
        let deadline = Instant::now() + window;
        let mut stop = false;
        while rows < max_rows {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Job(j)) => {
                    if j.expired() {
                        shed_expired(j, metrics, pressure);
                        continue;
                    }
                    let same_model = Arc::ptr_eq(&j.model, &batch[0].model);
                    if same_model && rows + j.inputs.rows() <= max_rows {
                        rows += j.inputs.rows();
                        batch.push(j);
                    } else {
                        // different model, or this job would overflow the
                        // batch — dispatch it in a later round
                        carry.push_back(j);
                    }
                }
                Err(RecvTimeoutError::Timeout) => break,
                Ok(Msg::Shutdown) | Err(RecvTimeoutError::Disconnected) => {
                    stop = true;
                    break;
                }
            }
        }
        window_span.set_arg(rows as u64);
        dispatch(batch, metrics, pressure, breaker);
        rate.tick(pressure);
        drop(window_span);
        if stop {
            // answer everything still queued, one dispatch each
            while let Some(j) = carry.pop_front() {
                dispatch(vec![j], metrics, pressure, breaker);
            }
            break 'outer;
        }
    }
}

/// Answer an expired job (503 at the router) and record its queue wait.
fn shed_expired(job: PredictJob, metrics: &ServeMetrics, pressure: &QueuePressure) {
    let waited = job.enqueued.elapsed();
    metrics.queue_wait.observe(waited.as_secs_f64());
    metrics.deadline_shed.inc();
    let _ = job.reply.send(Err(PredictFail::Deadline { waited }));
    pressure.job_done();
}

/// `model.predict` behind `catch_unwind`: a poisoned model (or the
/// `serve.predict.panic` failpoint) becomes a per-model breaker strike
/// instead of killing the dispatcher and burning a respawn.
fn predict_guarded(model: &ServedModel, x: &Tensor) -> Result<Tensor, PredictFail> {
    let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
        // failpoint: `serve.predict.panic` — a predict dying inside the
        // kernel; caught here and charged to the model's breaker
        failpoint::panic_point("serve.predict.panic");
        model.predict(x)
    }));
    match result {
        Ok(Ok(y)) => Ok(y),
        Ok(Err(e)) => Err(PredictFail::Failed(format!("{e:#}"))),
        Err(_) => Err(PredictFail::Panicked),
    }
}

/// Run one coalesced GEMM and fan the output rows back out.
fn dispatch(
    batch: Vec<PredictJob>,
    metrics: &ServeMetrics,
    pressure: &QueuePressure,
    breaker: &CircuitBreaker,
) {
    // Final deadline sweep: the batch window may have outlasted a job's
    // budget — shed it now, before the GEMM spends anything on it.
    let mut live = Vec::with_capacity(batch.len());
    for job in batch {
        if job.expired() {
            shed_expired(job, metrics, pressure);
        } else {
            live.push(job);
        }
    }
    if live.is_empty() {
        return;
    }
    let rows: usize = live.iter().map(|j| j.inputs.rows()).sum();
    metrics.predict_batches.inc();
    metrics.batch_size.observe(rows as f64);
    for job in &live {
        metrics.queue_wait.observe(job.enqueued.elapsed().as_secs_f64());
    }

    let model = Arc::clone(&live[0].model);
    let result = if live.len() == 1 {
        predict_guarded(&model, &live[0].inputs)
    } else {
        let n_in = model.n_in();
        let mut x = Tensor::zeros(rows, n_in);
        let mut off = 0;
        for job in &live {
            let r = job.inputs.rows();
            x.data_mut()[off * n_in..(off + r) * n_in].copy_from_slice(job.inputs.data());
            off += r;
        }
        predict_guarded(&model, &x)
    };

    match result {
        Ok(y) => {
            breaker.record_success(&model.name);
            if live.len() == 1 {
                let job = live.into_iter().next().unwrap();
                let _ = job.reply.send(Ok(y));
                pressure.job_done();
                return;
            }
            let n_out = y.cols();
            let mut off = 0;
            for job in live {
                let r = job.inputs.rows();
                let mut out = Tensor::zeros(r, n_out);
                out.data_mut()
                    .copy_from_slice(&y.data()[off * n_out..(off + r) * n_out]);
                off += r;
                let _ = job.reply.send(Ok(out));
                pressure.job_done();
            }
        }
        Err(fail) => {
            if matches!(fail, PredictFail::Panicked) {
                metrics.predict_panics.inc();
            }
            if breaker.record_failure(&model.name) {
                metrics.breaker_opens.inc();
                eprintln!(
                    "serve: circuit breaker opened for model '{}' ({fail})",
                    model.name
                );
            }
            for job in live {
                let _ = job.reply.send(Err(fail.clone()));
                pressure.job_done();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Arch;
    use crate::rng::Rng;

    fn model(dims: Vec<usize>, seed: u64) -> Arc<ServedModel> {
        let arch = Arch::new(dims).unwrap();
        let params = arch.init_params(&mut Rng::new(seed));
        Arc::new(ServedModel::from_params("t", params, None).unwrap())
    }

    fn start(window: Duration, max_rows: usize, metrics: &Arc<ServeMetrics>) -> Batcher {
        Batcher::start(
            BatcherConfig {
                window,
                max_rows,
                ..BatcherConfig::default()
            },
            Arc::clone(metrics),
            Arc::new(CircuitBreaker::new()),
        )
    }

    fn submit(
        handle: &BatcherHandle,
        model: &Arc<ServedModel>,
        x: Tensor,
    ) -> Receiver<Result<Tensor, PredictFail>> {
        let (tx, rx) = sync_channel(1);
        handle
            .submit(PredictJob::new(Arc::clone(model), x, tx))
            .unwrap();
        rx
    }

    #[test]
    fn zero_window_serves_single_requests() {
        // every test that spawns a Batcher holds the guard: the dispatch
        // loop checks process-global failpoints, so a concurrently
        // running armed test would otherwise leak its fault in here
        let _serial = failpoint::serial_guard();
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = start(Duration::ZERO, 64, &metrics);
        let m = model(vec![3, 5, 2], 1);
        let x = Tensor::from_fn(1, 3, |_, c| c as f32 * 0.25);
        let expected = m.predict(&x).unwrap();
        let rx = submit(&batcher.handle(), &m, x);
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got, expected);
        drop(batcher);
        assert_eq!(metrics.predict_batches.get(), 1);
    }

    #[test]
    fn window_coalesces_and_splits_bit_identically() {
        let _serial = failpoint::serial_guard();
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = start(Duration::from_millis(200), 64, &metrics);
        let m = model(vec![4, 6, 3], 2);
        let handle = batcher.handle();
        // Three jobs submitted well inside one 200 ms window.
        let xs: Vec<Tensor> = (0..3)
            .map(|k| Tensor::from_fn(1 + k, 4, |r, c| (k * 7 + r * 4 + c) as f32 * 0.1 - 0.4))
            .collect();
        let expected: Vec<Tensor> = xs.iter().map(|x| m.predict(x).unwrap()).collect();
        let rxs: Vec<_> = xs.into_iter().map(|x| submit(&handle, &m, x)).collect();
        for (rx, want) in rxs.into_iter().zip(&expected) {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(&got, want, "batched rows bit-identical to solo predict");
        }
        drop(batcher);
        // 1+2+3 rows; coalescing means fewer dispatches than jobs.
        assert_eq!(metrics.batch_size.count(), metrics.predict_batches.get());
        assert!(
            metrics.predict_batches.get() <= 2,
            "expected coalescing, got {} dispatches",
            metrics.predict_batches.get()
        );
    }

    #[test]
    fn max_rows_caps_a_batch() {
        let _serial = failpoint::serial_guard();
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = start(Duration::from_millis(100), 2, &metrics);
        let m = model(vec![2, 3, 1], 3);
        let handle = batcher.handle();
        let rxs: Vec<_> = (0..4)
            .map(|k| {
                submit(
                    &handle,
                    &m,
                    Tensor::from_fn(1, 2, |_, c| (k * 2 + c) as f32),
                )
            })
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        drop(batcher);
        assert!(
            metrics.predict_batches.get() >= 2,
            "4 rows with max_rows=2 needs >= 2 dispatches"
        );
    }

    #[test]
    fn different_models_never_share_a_gemm() {
        let _serial = failpoint::serial_guard();
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = start(Duration::from_millis(100), 64, &metrics);
        let m1 = model(vec![3, 4, 2], 4);
        let m2 = model(vec![3, 4, 2], 5); // same shape, different weights
        let x = Tensor::from_fn(1, 3, |_, c| c as f32 * 0.3);
        let e1 = m1.predict(&x).unwrap();
        let e2 = m2.predict(&x).unwrap();
        let handle = batcher.handle();
        let r1 = submit(&handle, &m1, x.clone());
        let r2 = submit(&handle, &m2, x.clone());
        assert_eq!(r1.recv().unwrap().unwrap(), e1);
        assert_eq!(r2.recv().unwrap().unwrap(), e2);
        drop(batcher);
        assert_eq!(metrics.predict_batches.get(), 2);
    }

    #[test]
    fn expired_job_is_shed_before_the_gemm() {
        let _serial = failpoint::serial_guard();
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = start(Duration::ZERO, 8, &metrics);
        let m = model(vec![2, 2], 11);
        let (tx, rx) = sync_channel(1);
        let job = PredictJob::new(Arc::clone(&m), Tensor::zeros(1, 2), tx)
            .with_deadline(Some(Instant::now()));
        batcher.handle().submit(job).unwrap();
        match rx.recv().unwrap() {
            Err(PredictFail::Deadline { .. }) => {}
            other => panic!("expected deadline shed, got {other:?}"),
        }
        // a job with headroom still gets served
        let (tx, rx) = sync_channel(1);
        let job = PredictJob::new(Arc::clone(&m), Tensor::zeros(1, 2), tx)
            .with_deadline(Some(Instant::now() + Duration::from_secs(30)));
        batcher.handle().submit(job).unwrap();
        assert!(rx.recv().unwrap().is_ok());
        drop(batcher);
        assert_eq!(metrics.deadline_shed.get(), 1);
        assert_eq!(
            metrics.predict_batches.get(),
            1,
            "the expired job must never reach a GEMM"
        );
        assert_eq!(metrics.queue_wait.count(), 2, "both jobs record queue wait");
    }

    #[test]
    fn predict_panic_is_caught_and_strikes_the_breaker() {
        let _serial = failpoint::serial_guard();
        let metrics = Arc::new(ServeMetrics::new());
        let breaker = Arc::new(CircuitBreaker::with(1, Duration::from_secs(60)));
        let batcher = Batcher::start(
            BatcherConfig {
                window: Duration::ZERO,
                max_rows: 8,
                ..BatcherConfig::default()
            },
            Arc::clone(&metrics),
            Arc::clone(&breaker),
        );
        let m = model(vec![2, 2], 12);
        let handle = batcher.handle();
        {
            let _fp =
                failpoint::scoped_at("serve.predict.panic", failpoint::FailAction::Panic, 1);
            let rx = submit(&handle, &m, Tensor::zeros(1, 2));
            match rx.recv().unwrap() {
                Err(PredictFail::Panicked) => {}
                other => panic!("expected panicked reply, got {other:?}"),
            }
        }
        // the dispatcher survived (no respawn burned) and keeps serving
        let rx = submit(&handle, &m, Tensor::zeros(1, 2));
        assert!(rx.recv().unwrap().is_ok());
        drop(batcher);
        assert_eq!(metrics.batcher_restarts.get(), 0);
        assert_eq!(metrics.predict_panics.get(), 1);
        assert_eq!(metrics.breaker_opens.get(), 1, "threshold-1 breaker opened");
    }

    #[test]
    fn queue_stall_failpoint_backs_up_the_queue() {
        let _serial = failpoint::serial_guard();
        let metrics = Arc::new(ServeMetrics::new());
        // armed before start, so the dispatcher's first loop iteration
        // stalls before it can pop the job
        let _fp = failpoint::scoped("serve.queue.stall", failpoint::FailAction::Error);
        let batcher = start(Duration::ZERO, 8, &metrics);
        let m = model(vec![2, 2], 13);
        let handle = batcher.handle();
        // a 1 ms deadline cannot survive the 25 ms stall — the job is
        // shed before the GEMM instead of served late
        let (tx, rx) = sync_channel(1);
        let job = PredictJob::new(Arc::clone(&m), Tensor::zeros(1, 2), tx)
            .with_deadline(Some(Instant::now() + Duration::from_millis(1)));
        handle.submit(job).unwrap();
        match rx.recv().unwrap() {
            Err(PredictFail::Deadline { waited }) => {
                assert!(waited >= Duration::from_millis(1));
            }
            other => panic!("expected deadline shed under stall, got {other:?}"),
        }
        assert_eq!(metrics.predict_batches.get(), 0);
    }

    #[test]
    fn brownout_enters_at_three_quarters_and_exits_at_one_quarter() {
        let mut b = Brownout::new(16);
        assert_eq!(b.observe(0), None);
        assert_eq!(b.observe(11), None, "below 3/4 stays out");
        assert_eq!(b.observe(12), Some(true), "3/4 full enters");
        assert_eq!(b.observe(13), None, "already in");
        assert_eq!(b.observe(5), None, "above 1/4 stays in (hysteresis)");
        assert_eq!(b.observe(4), Some(false), "1/4 exits");
        assert_eq!(b.observe(4), None);
        // degenerate bound never divides by zero
        let mut tiny = Brownout::new(0);
        assert_eq!(tiny.observe(1), Some(true));
    }

    #[test]
    fn full_queue_failpoint_sheds_with_overloaded() {
        let _serial = failpoint::serial_guard();
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = start(Duration::ZERO, 8, &metrics);
        let m = model(vec![2, 2], 7);
        let handle = batcher.handle();
        {
            let _fp = failpoint::scoped("serve.batcher.full", failpoint::FailAction::Error);
            let (tx, _rx) = sync_channel(1);
            let err = handle
                .submit(PredictJob::new(Arc::clone(&m), Tensor::zeros(1, 2), tx))
                .unwrap_err();
            assert_eq!(err, SubmitError::Overloaded);
        }
        // disarmed again: the same submit goes through
        let rx = submit(&handle, &m, Tensor::zeros(1, 2));
        assert!(rx.recv().unwrap().is_ok());
    }

    #[test]
    fn zero_submit_wait_sheds_immediately_on_a_full_queue() {
        let _serial = failpoint::serial_guard();
        let metrics = Arc::new(ServeMetrics::new());
        // a stalled queue of depth 1 with no dispatcher drain: fill it,
        // then a zero-wait submit must shed without sleeping
        let _fp = failpoint::scoped("serve.queue.stall", failpoint::FailAction::Error);
        let batcher = Batcher::start(
            BatcherConfig {
                window: Duration::ZERO,
                max_rows: 8,
                max_queue: 1,
                submit_wait: Duration::ZERO,
            },
            Arc::clone(&metrics),
            Arc::new(CircuitBreaker::new()),
        );
        let m = model(vec![2, 2], 14);
        let handle = batcher.handle();
        // saturate: with the dispatcher stalling, at least one of a
        // burst of zero-wait submits must observe a full queue
        let mut shed = 0;
        let mut rxs = Vec::new();
        for _ in 0..64 {
            let (tx, rx) = sync_channel(1);
            match handle.submit(PredictJob::new(Arc::clone(&m), Tensor::zeros(1, 2), tx)) {
                Ok(()) => rxs.push(rx),
                Err(SubmitError::Overloaded) => shed += 1,
                Err(e) => panic!("unexpected submit error {e:?}"),
            }
        }
        assert!(shed > 0, "zero-wait submit never shed on a full queue");
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok(), "accepted jobs are answered");
        }
    }

    #[test]
    fn panicked_dispatcher_turns_submits_into_down() {
        let _serial = failpoint::serial_guard();
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = {
            let _fp = failpoint::scoped("serve.batcher.panic", failpoint::FailAction::Panic);
            let b = start(Duration::ZERO, 8, &metrics);
            // the persistent panic burns the whole restart budget; wait
            // for the channel to disconnect (submits before that may be
            // accepted into the dying queue and are never answered)
            let m = model(vec![2, 2], 8);
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                let (tx, _rx) = sync_channel(1);
                match b
                    .handle()
                    .submit(PredictJob::new(Arc::clone(&m), Tensor::zeros(1, 2), tx))
                {
                    Err(SubmitError::Down) => break,
                    _ => {
                        assert!(
                            Instant::now() < deadline,
                            "dispatcher never went down after injected panic"
                        );
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            }
            b
        };
        drop(batcher);
        assert_eq!(metrics.batcher_restarts.get(), MAX_DISPATCHER_RESTARTS);
    }

    #[test]
    fn dispatcher_restarts_after_transient_panic() {
        let _serial = failpoint::serial_guard();
        let metrics = Arc::new(ServeMetrics::new());
        // Armed before start, so the dispatcher's very first loop
        // iteration panics exactly once and the failpoint disarms
        // itself; the supervisor respawns the loop.
        let _fp = failpoint::scoped_at("serve.batcher.panic", failpoint::FailAction::Panic, 1);
        let batcher = start(Duration::ZERO, 8, &metrics);
        let m = model(vec![2, 2], 9);
        // The queued job is answered by the respawned dispatcher — the
        // reply is the synchronization point proving the restart landed.
        let rx = submit(&batcher.handle(), &m, Tensor::zeros(1, 2));
        assert!(rx.recv().unwrap().is_ok());
        assert_eq!(metrics.batcher_restarts.get(), 1);
        drop(batcher);
    }

    #[test]
    fn shutdown_answers_queued_jobs() {
        let _serial = failpoint::serial_guard();
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = start(Duration::from_millis(50), 8, &metrics);
        let m = model(vec![2, 2], 6);
        let rx = submit(&batcher.handle(), &m, Tensor::zeros(1, 2));
        drop(batcher); // join — the queued job must still be answered
        assert!(rx.recv().unwrap().is_ok());
    }
}
