//! Micro-batching predict dispatcher: concurrent `POST /predict`
//! requests landing within one batch window are coalesced into a single
//! GEMM over the shared [`crate::util::pool::WorkerPool`], so
//! per-request cost amortizes exactly like training batches do.
//!
//! One dispatcher thread owns the queue: it takes the oldest pending
//! job, keeps the window open for up to `window` (or until `max_rows`
//! rows accumulate), stacks every same-model job's rows into one input
//! tensor, runs one `predict`, and splits the output rows back to the
//! per-request reply channels. Jobs for a *different* model arriving
//! inside the window are carried over and dispatched next round.
//!
//! Determinism: the native predict GEMM accumulates every output element
//! in a fixed per-row order independent of the other rows in the batch
//! (see `linalg::gemm`), and scaling is elementwise — so a micro-batched
//! response is bit-identical to the same request served alone, whatever
//! the coalescing, thread count, or batch composition.

use super::registry::ServedModel;
use crate::metrics::serve::ServeMetrics;
use crate::tensor::Tensor;
use crate::util::failpoint;
use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Queue depth before submits start waiting (backpressure).
const QUEUE_DEPTH: usize = 1024;

/// Longest a submit waits on a full queue before shedding the request.
/// Bounded so a wedged dispatcher turns into load shedding (HTTP 429 at
/// the router), never an indefinitely blocked connection thread.
const SUBMIT_WAIT: Duration = Duration::from_millis(50);

/// Client back-off hint surfaced as `Retry-After` on a shed response.
pub const RETRY_AFTER_SECS: u64 = 1;

/// Dispatcher respawns allowed after panics before the batcher goes
/// permanently down (submits answer `Down`, the router 503s). Bounded so
/// a deterministic panic (poisoned model state, corrupt job) cannot spin
/// the respawn loop forever; each respawn increments
/// `dmdtrain_batcher_restarts_total`.
pub const MAX_DISPATCHER_RESTARTS: u64 = 3;

/// Why a submit was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The queue stayed full for the whole bounded wait — the request
    /// is shed (the router answers 429 + `Retry-After`).
    Overloaded,
    /// The dispatcher thread is gone (shutdown or crash) — 503.
    Down,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "predict queue is full"),
            SubmitError::Down => write!(f, "predict dispatcher is down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// One predict request in flight.
pub struct PredictJob {
    pub model: Arc<ServedModel>,
    /// (rows, n_in) input tensor — shape pre-validated by the router.
    pub inputs: Tensor,
    /// Receives the (rows, n_out) result.
    pub reply: SyncSender<anyhow::Result<Tensor>>,
}

enum Msg {
    Job(PredictJob),
    Shutdown,
}

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// How long the dispatcher keeps a batch open for more rows.
    /// `Duration::ZERO` disables coalescing (every request runs alone).
    pub window: Duration,
    /// Row cap per dispatched GEMM.
    pub max_rows: usize,
}

/// Handle used by request threads to submit jobs. Each connection
/// thread owns its clone, so the `SyncSender` is never shared by
/// reference across threads.
pub struct BatcherHandle {
    tx: SyncSender<Msg>,
}

impl Clone for BatcherHandle {
    fn clone(&self) -> Self {
        BatcherHandle {
            tx: self.tx.clone(),
        }
    }
}

impl BatcherHandle {
    /// Enqueue a job. Waits at most [`SUBMIT_WAIT`] when the queue is
    /// full, then sheds with [`SubmitError::Overloaded`] — submit never
    /// blocks a connection thread indefinitely.
    pub fn submit(&self, job: PredictJob) -> Result<(), SubmitError> {
        // failpoint: `serve.batcher.full` simulates a saturated queue
        if failpoint::fire("serve.batcher.full").is_some() {
            return Err(SubmitError::Overloaded);
        }
        let mut msg = Msg::Job(job);
        let deadline = Instant::now() + SUBMIT_WAIT;
        loop {
            match self.tx.try_send(msg) {
                Ok(()) => return Ok(()),
                Err(TrySendError::Disconnected(_)) => return Err(SubmitError::Down),
                Err(TrySendError::Full(m)) => {
                    if Instant::now() >= deadline {
                        return Err(SubmitError::Overloaded);
                    }
                    msg = m;
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
    }
}

/// The dispatcher thread plus its submit side. Dropping the `Batcher`
/// sends a shutdown sentinel and joins the thread (pending jobs are
/// still answered).
pub struct Batcher {
    tx: SyncSender<Msg>,
    thread: Option<JoinHandle<()>>,
}

impl Batcher {
    pub fn start(cfg: BatcherConfig, metrics: Arc<ServeMetrics>) -> Batcher {
        let (tx, rx) = sync_channel::<Msg>(QUEUE_DEPTH);
        let thread = std::thread::Builder::new()
            .name("dmdtrain-batcher".to_string())
            .spawn(move || {
                // Self-healing: a panicked dispatch loop is respawned up
                // to MAX_DISPATCHER_RESTARTS times. The queue survives a
                // respawn — `rx` lives here, outside the loop — so jobs
                // submitted around the panic are still answered. Past the
                // cap the batcher goes permanently down (submits answer
                // `Down`, the router 503s).
                let mut restarts: u64 = 0;
                loop {
                    match std::panic::catch_unwind(AssertUnwindSafe(|| run(&rx, cfg, &metrics))) {
                        Ok(()) => break,
                        Err(_) if restarts < MAX_DISPATCHER_RESTARTS => {
                            restarts += 1;
                            metrics.batcher_restarts.inc();
                            eprintln!(
                                "serve: predict dispatcher panicked; respawning \
                                 ({restarts}/{MAX_DISPATCHER_RESTARTS})"
                            );
                        }
                        Err(_) => {
                            eprintln!(
                                "serve: predict dispatcher panicked {} times; \
                                 restart budget exhausted, batcher is down",
                                restarts + 1
                            );
                            break;
                        }
                    }
                }
            })
            .expect("spawn batcher thread");
        Batcher {
            tx,
            thread: Some(thread),
        }
    }

    pub fn handle(&self) -> BatcherHandle {
        BatcherHandle {
            tx: self.tx.clone(),
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn run(rx: &Receiver<Msg>, cfg: BatcherConfig, metrics: &ServeMetrics) {
    let max_rows = cfg.max_rows.max(1);
    let mut carry: VecDeque<PredictJob> = VecDeque::new();
    'outer: loop {
        // failpoint: `serve.batcher.panic` kills the dispatch loop. The
        // supervisor in `Batcher::start` respawns it up to
        // MAX_DISPATCHER_RESTARTS times; a persistent panic burns the
        // budget and submits then fail with `Down` — the router answers
        // 503 instead of hanging (asserted in tests/fault_injection.rs)
        failpoint::panic_point("serve.batcher.panic");
        // Head job: oldest carried-over job, else block for the next one.
        let head = match carry.pop_front() {
            Some(j) => j,
            None => match rx.recv() {
                Ok(Msg::Job(j)) => j,
                Ok(Msg::Shutdown) | Err(_) => break 'outer,
            },
        };
        // span covers the open window plus the coalesced dispatch;
        // arg carries the final row count of the batch
        let mut window_span = crate::obs::span("batch_window");
        let mut rows = head.inputs.rows();
        let mut batch = vec![head];
        let deadline = Instant::now() + cfg.window;
        let mut stop = false;
        while rows < max_rows {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Job(j)) => {
                    let same_model = Arc::ptr_eq(&j.model, &batch[0].model);
                    if same_model && rows + j.inputs.rows() <= max_rows {
                        rows += j.inputs.rows();
                        batch.push(j);
                    } else {
                        // different model, or this job would overflow the
                        // batch — dispatch it in a later round
                        carry.push_back(j);
                    }
                }
                Err(RecvTimeoutError::Timeout) => break,
                Ok(Msg::Shutdown) | Err(RecvTimeoutError::Disconnected) => {
                    stop = true;
                    break;
                }
            }
        }
        window_span.set_arg(rows as u64);
        dispatch(batch, rows, metrics);
        drop(window_span);
        if stop {
            // answer everything still queued, one dispatch each
            while let Some(j) = carry.pop_front() {
                let rows = j.inputs.rows();
                dispatch(vec![j], rows, metrics);
            }
            break 'outer;
        }
    }
}

/// Run one coalesced GEMM and fan the output rows back out.
fn dispatch(batch: Vec<PredictJob>, rows: usize, metrics: &ServeMetrics) {
    metrics.predict_batches.inc();
    metrics.batch_size.observe(rows as f64);

    if batch.len() == 1 {
        let job = batch.into_iter().next().unwrap();
        let result = job.model.predict(&job.inputs);
        let _ = job.reply.send(result);
        return;
    }

    let model = Arc::clone(&batch[0].model);
    let n_in = model.n_in();
    let mut x = Tensor::zeros(rows, n_in);
    let mut off = 0;
    for job in &batch {
        let r = job.inputs.rows();
        x.data_mut()[off * n_in..(off + r) * n_in].copy_from_slice(job.inputs.data());
        off += r;
    }
    match model.predict(&x) {
        Ok(y) => {
            let n_out = y.cols();
            let mut off = 0;
            for job in batch {
                let r = job.inputs.rows();
                let mut out = Tensor::zeros(r, n_out);
                out.data_mut()
                    .copy_from_slice(&y.data()[off * n_out..(off + r) * n_out]);
                off += r;
                let _ = job.reply.send(Ok(out));
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for job in batch {
                let _ = job
                    .reply
                    .send(Err(anyhow::anyhow!("batched predict failed: {msg}")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Arch;
    use crate::rng::Rng;

    fn model(dims: Vec<usize>, seed: u64) -> Arc<ServedModel> {
        let arch = Arch::new(dims).unwrap();
        let params = arch.init_params(&mut Rng::new(seed));
        Arc::new(ServedModel::from_params("t", params, None).unwrap())
    }

    fn submit(
        handle: &BatcherHandle,
        model: &Arc<ServedModel>,
        x: Tensor,
    ) -> Receiver<anyhow::Result<Tensor>> {
        let (tx, rx) = sync_channel(1);
        handle
            .submit(PredictJob {
                model: Arc::clone(model),
                inputs: x,
                reply: tx,
            })
            .unwrap();
        rx
    }

    #[test]
    fn zero_window_serves_single_requests() {
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::start(
            BatcherConfig {
                window: Duration::ZERO,
                max_rows: 64,
            },
            Arc::clone(&metrics),
        );
        let m = model(vec![3, 5, 2], 1);
        let x = Tensor::from_fn(1, 3, |_, c| c as f32 * 0.25);
        let expected = m.predict(&x).unwrap();
        let rx = submit(&batcher.handle(), &m, x);
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got, expected);
        drop(batcher);
        assert_eq!(metrics.predict_batches.get(), 1);
    }

    #[test]
    fn window_coalesces_and_splits_bit_identically() {
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::start(
            BatcherConfig {
                window: Duration::from_millis(200),
                max_rows: 64,
            },
            Arc::clone(&metrics),
        );
        let m = model(vec![4, 6, 3], 2);
        let handle = batcher.handle();
        // Three jobs submitted well inside one 200 ms window.
        let xs: Vec<Tensor> = (0..3)
            .map(|k| Tensor::from_fn(1 + k, 4, |r, c| (k * 7 + r * 4 + c) as f32 * 0.1 - 0.4))
            .collect();
        let expected: Vec<Tensor> = xs.iter().map(|x| m.predict(x).unwrap()).collect();
        let rxs: Vec<_> = xs.into_iter().map(|x| submit(&handle, &m, x)).collect();
        for (rx, want) in rxs.into_iter().zip(&expected) {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(&got, want, "batched rows bit-identical to solo predict");
        }
        drop(batcher);
        // 1+2+3 rows; coalescing means fewer dispatches than jobs.
        assert_eq!(metrics.batch_size.count(), metrics.predict_batches.get());
        assert!(
            metrics.predict_batches.get() <= 2,
            "expected coalescing, got {} dispatches",
            metrics.predict_batches.get()
        );
    }

    #[test]
    fn max_rows_caps_a_batch() {
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::start(
            BatcherConfig {
                window: Duration::from_millis(100),
                max_rows: 2,
            },
            Arc::clone(&metrics),
        );
        let m = model(vec![2, 3, 1], 3);
        let handle = batcher.handle();
        let rxs: Vec<_> = (0..4)
            .map(|k| {
                submit(
                    &handle,
                    &m,
                    Tensor::from_fn(1, 2, |_, c| (k * 2 + c) as f32),
                )
            })
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        drop(batcher);
        assert!(
            metrics.predict_batches.get() >= 2,
            "4 rows with max_rows=2 needs >= 2 dispatches"
        );
    }

    #[test]
    fn different_models_never_share_a_gemm() {
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::start(
            BatcherConfig {
                window: Duration::from_millis(100),
                max_rows: 64,
            },
            Arc::clone(&metrics),
        );
        let m1 = model(vec![3, 4, 2], 4);
        let m2 = model(vec![3, 4, 2], 5); // same shape, different weights
        let x = Tensor::from_fn(1, 3, |_, c| c as f32 * 0.3);
        let e1 = m1.predict(&x).unwrap();
        let e2 = m2.predict(&x).unwrap();
        let handle = batcher.handle();
        let r1 = submit(&handle, &m1, x.clone());
        let r2 = submit(&handle, &m2, x.clone());
        assert_eq!(r1.recv().unwrap().unwrap(), e1);
        assert_eq!(r2.recv().unwrap().unwrap(), e2);
        drop(batcher);
        assert_eq!(metrics.predict_batches.get(), 2);
    }

    #[test]
    fn full_queue_failpoint_sheds_with_overloaded() {
        let _serial = failpoint::serial_guard();
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::start(
            BatcherConfig {
                window: Duration::ZERO,
                max_rows: 8,
            },
            Arc::clone(&metrics),
        );
        let m = model(vec![2, 2], 7);
        let handle = batcher.handle();
        {
            let _fp = failpoint::scoped("serve.batcher.full", failpoint::FailAction::Error);
            let (tx, _rx) = sync_channel(1);
            let err = handle
                .submit(PredictJob {
                    model: Arc::clone(&m),
                    inputs: Tensor::zeros(1, 2),
                    reply: tx,
                })
                .unwrap_err();
            assert_eq!(err, SubmitError::Overloaded);
        }
        // disarmed again: the same submit goes through
        let rx = submit(&handle, &m, Tensor::zeros(1, 2));
        assert!(rx.recv().unwrap().is_ok());
    }

    #[test]
    fn panicked_dispatcher_turns_submits_into_down() {
        let _serial = failpoint::serial_guard();
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = {
            let _fp = failpoint::scoped("serve.batcher.panic", failpoint::FailAction::Panic);
            let b = Batcher::start(
                BatcherConfig {
                    window: Duration::ZERO,
                    max_rows: 8,
                },
                Arc::clone(&metrics),
            );
            // the persistent panic burns the whole restart budget; wait
            // for the channel to disconnect (submits before that may be
            // accepted into the dying queue and are never answered)
            let m = model(vec![2, 2], 8);
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                let (tx, _rx) = sync_channel(1);
                match b.handle().submit(PredictJob {
                    model: Arc::clone(&m),
                    inputs: Tensor::zeros(1, 2),
                    reply: tx,
                }) {
                    Err(SubmitError::Down) => break,
                    _ => {
                        assert!(
                            Instant::now() < deadline,
                            "dispatcher never went down after injected panic"
                        );
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            }
            b
        };
        drop(batcher);
        assert_eq!(metrics.batcher_restarts.get(), MAX_DISPATCHER_RESTARTS);
    }

    #[test]
    fn dispatcher_restarts_after_transient_panic() {
        let _serial = failpoint::serial_guard();
        let metrics = Arc::new(ServeMetrics::new());
        // Armed before start, so the dispatcher's very first loop
        // iteration panics exactly once and the failpoint disarms
        // itself; the supervisor respawns the loop.
        let _fp = failpoint::scoped_at("serve.batcher.panic", failpoint::FailAction::Panic, 1);
        let batcher = Batcher::start(
            BatcherConfig {
                window: Duration::ZERO,
                max_rows: 8,
            },
            Arc::clone(&metrics),
        );
        let m = model(vec![2, 2], 9);
        // The queued job is answered by the respawned dispatcher — the
        // reply is the synchronization point proving the restart landed.
        let rx = submit(&batcher.handle(), &m, Tensor::zeros(1, 2));
        assert!(rx.recv().unwrap().is_ok());
        assert_eq!(metrics.batcher_restarts.get(), 1);
        drop(batcher);
    }

    #[test]
    fn shutdown_answers_queued_jobs() {
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::start(
            BatcherConfig {
                window: Duration::from_millis(50),
                max_rows: 8,
            },
            Arc::clone(&metrics),
        );
        let m = model(vec![2, 2], 6);
        let rx = submit(&batcher.handle(), &m, Tensor::zeros(1, 2));
        drop(batcher); // join — the queued job must still be answered
        assert!(rx.recv().unwrap().is_ok());
    }
}
