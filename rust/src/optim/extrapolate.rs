//! Per-weight extrapolation baseline (paper §2 related work).
//!
//! Kamarthi & Pittner (1999) accelerate training by fitting each weight's
//! trajectory independently and extrapolating toward its converged value.
//! The paper argues this *breaks the coherent per-layer dynamics* in large
//! DNNs (citing Hoskins et al. 2019) — unlike DMD, which fits one reduced
//! operator per layer. We implement the simplest faithful member of that
//! family: an ordinary-least-squares line fit per weight over the last `m`
//! snapshots, extrapolated `s` steps ahead. `benches/baseline_extrapolation`
//! compares it against DMD under identical budgets (experiment E10).

use crate::dmd::SnapshotBuffer;

/// Per-weight line-fit extrapolator sharing the DMD snapshot plumbing.
pub struct WeightExtrapolation;

impl WeightExtrapolation {
    /// Extrapolate every weight `steps` ahead with an OLS line fit over
    /// the buffer's columns. Returns the new flattened weights.
    pub fn extrapolate(buffer: &SnapshotBuffer, steps: usize) -> anyhow::Result<Vec<f32>> {
        let cols = buffer.columns();
        let m = cols.len();
        anyhow::ensure!(m >= 2, "extrapolation needs ≥ 2 snapshots");
        let n = cols[0].len();

        // OLS slope/intercept over t = 0..m-1, evaluated at t = m-1+steps.
        // slope_j = Σ_t (t - t̄)(w_tj - w̄_j) / Σ_t (t - t̄)²
        let t_mean = (m as f64 - 1.0) / 2.0;
        let denom: f64 = (0..m).map(|t| (t as f64 - t_mean).powi(2)).sum();
        let t_eval = (m - 1 + steps) as f64;

        let mut out = vec![0.0f32; n];
        for j in 0..n {
            let mut w_mean = 0.0f64;
            for col in &cols {
                w_mean += col[j] as f64;
            }
            w_mean /= m as f64;
            let mut num = 0.0f64;
            for (t, col) in cols.iter().enumerate() {
                num += (t as f64 - t_mean) * (col[j] as f64 - w_mean);
            }
            let slope = num / denom;
            out[j] = (w_mean + slope * (t_eval - t_mean)) as f32;
        }
        anyhow::ensure!(out.iter().all(|v| v.is_finite()), "non-finite extrapolation");
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_on_linear_trajectories() {
        // w_j(t) = a_j + b_j t is recovered exactly.
        let mut buf = SnapshotBuffer::new(5);
        for t in 0..5 {
            let w: Vec<f32> = (0..4)
                .map(|j| (j as f32 + 1.0) + (0.5 * j as f32) * t as f32)
                .collect();
            buf.push(t, &w);
        }
        let out = WeightExtrapolation::extrapolate(&buf, 10).unwrap();
        for (j, &v) in out.iter().enumerate() {
            let want = (j as f32 + 1.0) + (0.5 * j as f32) * 14.0;
            assert!((v - want).abs() < 1e-4, "j={j}: {v} vs {want}");
        }
    }

    #[test]
    fn line_fit_overshoots_geometric_decay() {
        // The known failure mode vs DMD: a geometric approach to a fixed
        // point is extrapolated *past* the fixed point by a line fit.
        let mut buf = SnapshotBuffer::new(6);
        let mut w = 1.0f32;
        for t in 0..6 {
            buf.push(t, &[w]);
            w *= 0.5; // converging to 0 from above
        }
        let out = WeightExtrapolation::extrapolate(&buf, 50).unwrap();
        assert!(out[0] < 0.0, "line fit should overshoot below 0, got {}", out[0]);
    }

    #[test]
    fn zero_steps_is_endpoint_of_fit() {
        let mut buf = SnapshotBuffer::new(3);
        for t in 0..3 {
            buf.push(t, &[t as f32]);
        }
        let out = WeightExtrapolation::extrapolate(&buf, 0).unwrap();
        assert!((out[0] - 2.0).abs() < 1e-6);
    }
}
