//! Plain SGD and SGD with classical momentum.
//!
//! `Sgd` is the paper's "plain gradient descent" regime (no state at
//! all); `SgdMomentum` is the classical heavy-ball ablation baseline.
//! Both are selectable by name from `TrainConfig` (`train.optimizer`).

use super::{Optimizer, OptimizerState};
use crate::tensor::Tensor;

/// Plain gradient descent: `p ← p − lr·g`. Stateless.
pub struct Sgd {
    lr: f64,
}

impl Sgd {
    pub fn new(lr: f64) -> Self {
        Sgd { lr }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), grads.len());
        let lr = self.lr as f32;
        // gradients come in borrowed (typically from the session's
        // TrainWorkspace); lockstep slice walk, bounds checks hoisted
        for (param, grad) in params.iter_mut().zip(grads) {
            assert_eq!(param.len(), grad.len(), "param/grad shape mismatch");
            for (p, &g) in param.data_mut().iter_mut().zip(grad.data()) {
                *p -= lr * g;
            }
        }
    }

    fn reset(&mut self) {}

    fn name(&self) -> &'static str {
        "sgd"
    }

    fn export_state(&self) -> OptimizerState {
        OptimizerState {
            kind: "sgd".to_string(),
            t: 0,
            slots: Vec::new(),
        }
    }

    fn import_state(&mut self, st: &OptimizerState) -> anyhow::Result<()> {
        anyhow::ensure!(st.kind == "sgd", "state is for '{}', not sgd", st.kind);
        Ok(())
    }

    fn scale_lr(&mut self, factor: f64) {
        self.lr *= factor;
    }
}

/// SGD with classical momentum: `v ← μv − lr·g; p ← p + v`.
pub struct SgdMomentum {
    lr: f64,
    momentum: f64,
    velocity: Vec<Vec<f32>>,
}

impl SgdMomentum {
    pub fn new(lr: f64, momentum: f64) -> Self {
        SgdMomentum {
            lr,
            momentum,
            velocity: Vec::new(),
        }
    }
}

impl Optimizer for SgdMomentum {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), grads.len());
        if self.velocity.len() != params.len() {
            self.velocity = params.iter().map(|p| vec![0.0; p.len()]).collect();
        }
        let (lr, mu) = (self.lr as f32, self.momentum as f32);
        for ((param, grad), vel) in params.iter_mut().zip(grads).zip(&mut self.velocity) {
            assert_eq!(param.len(), grad.len(), "param/grad shape mismatch");
            // stale velocity (e.g. a mismatched import_state) must fail
            // loudly, not silently truncate the lockstep zip below
            assert_eq!(vel.len(), param.len(), "velocity/param length mismatch");
            // lockstep slice walk over workspace-borrowed gradients
            for ((p, &g), v) in param
                .data_mut()
                .iter_mut()
                .zip(grad.data())
                .zip(vel.iter_mut())
            {
                *v = mu * *v - lr * g;
                *p += *v;
            }
        }
    }

    fn reset(&mut self) {
        for v in &mut self.velocity {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    fn name(&self) -> &'static str {
        "sgd_momentum"
    }

    fn export_state(&self) -> OptimizerState {
        OptimizerState {
            kind: "sgd_momentum".to_string(),
            t: 0,
            slots: vec![self.velocity.clone()],
        }
    }

    fn import_state(&mut self, st: &OptimizerState) -> anyhow::Result<()> {
        anyhow::ensure!(
            st.kind == "sgd_momentum",
            "state is for '{}', not sgd_momentum",
            st.kind
        );
        anyhow::ensure!(st.slots.len() == 1, "sgd_momentum expects 1 state slot");
        self.velocity = st.slots[0].clone();
        Ok(())
    }

    fn scale_lr(&mut self, factor: f64) {
        self.lr *= factor;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_step() {
        let mut params = vec![Tensor::from_vec(1, 2, vec![1.0, 2.0])];
        let grads = vec![Tensor::from_vec(1, 2, vec![0.5, -0.5])];
        let mut opt = Sgd::new(0.1);
        opt.step(&mut params, &grads);
        assert!((params[0].get(0, 0) - 0.95).abs() < 1e-7);
        assert!((params[0].get(0, 1) - 2.05).abs() < 1e-7);
    }

    #[test]
    fn momentum_accumulates() {
        let mut params = vec![Tensor::from_vec(1, 1, vec![0.0])];
        let grads = vec![Tensor::from_vec(1, 1, vec![1.0])];
        let mut opt = SgdMomentum::new(0.1, 0.9);
        opt.step(&mut params, &grads); // v = -0.1, p = -0.1
        opt.step(&mut params, &grads); // v = -0.19, p = -0.29
        assert!((params[0].get(0, 0) + 0.29).abs() < 1e-6);
    }

    #[test]
    fn converges_on_quadratic() {
        let mut params = vec![Tensor::from_vec(1, 1, vec![4.0])];
        let mut opt = SgdMomentum::new(0.05, 0.9);
        for _ in 0..300 {
            let grads = params.clone();
            opt.step(&mut params, &grads);
        }
        assert!(params[0].get(0, 0).abs() < 1e-3);
    }

    #[test]
    fn momentum_state_roundtrip_is_exact() {
        let grads = vec![Tensor::from_vec(1, 2, vec![1.0, -2.0])];
        let mut a = SgdMomentum::new(0.1, 0.9);
        let mut pa = vec![Tensor::from_vec(1, 2, vec![0.3, 0.7])];
        for _ in 0..5 {
            a.step(&mut pa, &grads);
        }
        let st = a.export_state();
        let mut b = SgdMomentum::new(0.1, 0.9);
        b.import_state(&st).unwrap();
        let mut pb = pa.clone();
        a.step(&mut pa, &grads);
        b.step(&mut pb, &grads);
        assert_eq!(pa[0].data(), pb[0].data());
    }

    #[test]
    fn import_rejects_wrong_kind() {
        let st = Sgd::new(0.1).export_state();
        assert!(SgdMomentum::new(0.1, 0.9).import_state(&st).is_err());
    }
}
