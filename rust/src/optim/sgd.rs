//! SGD with classical momentum (ablation baseline).

use super::Optimizer;
use crate::tensor::Tensor;

pub struct Sgd {
    lr: f64,
    momentum: f64,
    velocity: Vec<Vec<f32>>,
}

impl Sgd {
    pub fn new(lr: f64, momentum: f64) -> Self {
        Sgd {
            lr,
            momentum,
            velocity: Vec::new(),
        }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        if self.velocity.len() != params.len() {
            self.velocity = params.iter().map(|p| vec![0.0; p.len()]).collect();
        }
        let (lr, mu) = (self.lr as f32, self.momentum as f32);
        for ((param, grad), vel) in params.iter_mut().zip(grads).zip(&mut self.velocity) {
            let pd = param.data_mut();
            let gd = grad.data();
            for j in 0..pd.len() {
                vel[j] = mu * vel[j] - lr * gd[j];
                pd[j] += vel[j];
            }
        }
    }

    fn reset(&mut self) {
        for v in &mut self.velocity {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_step() {
        let mut params = vec![Tensor::from_vec(1, 2, vec![1.0, 2.0])];
        let grads = vec![Tensor::from_vec(1, 2, vec![0.5, -0.5])];
        let mut opt = Sgd::new(0.1, 0.0);
        opt.step(&mut params, &grads);
        assert!((params[0].get(0, 0) - 0.95).abs() < 1e-7);
        assert!((params[0].get(0, 1) - 2.05).abs() < 1e-7);
    }

    #[test]
    fn momentum_accumulates() {
        let mut params = vec![Tensor::from_vec(1, 1, vec![0.0])];
        let grads = vec![Tensor::from_vec(1, 1, vec![1.0])];
        let mut opt = Sgd::new(0.1, 0.9);
        opt.step(&mut params, &grads); // v = -0.1, p = -0.1
        opt.step(&mut params, &grads); // v = -0.19, p = -0.29
        assert!((params[0].get(0, 0) + 0.29).abs() < 1e-6);
    }

    #[test]
    fn converges_on_quadratic() {
        let mut params = vec![Tensor::from_vec(1, 1, vec![4.0])];
        let mut opt = Sgd::new(0.05, 0.9);
        for _ in 0..300 {
            let grads = params.clone();
            opt.step(&mut params, &grads);
        }
        assert!(params[0].get(0, 0).abs() < 1e-3);
    }
}
