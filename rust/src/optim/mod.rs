//! Optimizers. The coordinator owns optimizer state (not the HLO graph) —
//! that is what exposes the weight stream to the DMD engine without the
//! extract/assign overhead the paper measured in TensorFlow (their 1.41×).
//!
//! * [`Adam`] — the paper's optimizer.
//! * [`Sgd`] — plain gradient descent (the paper's "plain GD" regime).
//! * [`SgdMomentum`] — classical heavy-ball momentum (ablation baseline).
//! * [`WeightExtrapolation`] — per-weight line-fit extrapolation, the
//!   related-work baseline (§2, Kamarthi & Pittner style) that DMD is
//!   claimed to beat because per-weight fits "break the coherent
//!   dynamics" — now a first-class accelerator
//!   (`trainer::accel::LineFitAccelerator`).
//!
//! The optimizer is chosen by name in `TrainConfig`
//! (`train.optimizer = "adam" | "sgd" | "sgd_momentum"`) and built via
//! [`from_name`]; every optimizer can export/import its full state
//! ([`OptimizerState`]) so resumed training is bit-identical.

mod adam;
mod extrapolate;
mod sgd;

pub use adam::Adam;
pub use extrapolate::WeightExtrapolation;
pub use sgd::{Sgd, SgdMomentum};

use crate::config::{AdamParams, SgdParams};
use crate::tensor::Tensor;

/// A first-order optimizer over a flat list of parameter tensors.
pub trait Optimizer {
    /// Apply one update in place. `grads` aligns with `params`.
    ///
    /// §Perf: `grads` is a borrow, so callers can hand in gradients
    /// resident in a `runtime::TrainWorkspace` (the `TrainSession` hot
    /// path does exactly that) — no per-step `Vec<Tensor>` collection
    /// is ever required by this trait.
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]);

    /// Reset internal state (moments, step counter). Called after a DMD
    /// jump when `reset_on_jump` is configured — ablatable: the paper
    /// keeps optimizer state implicit (TF), we default to keeping it.
    fn reset(&mut self);

    fn name(&self) -> &'static str;

    /// Snapshot the full internal state for checkpointing. Slot layout
    /// is optimizer-specific (Adam: `[m, v]`; momentum: `[velocity]`);
    /// each slot aligns with the parameter-tensor list.
    fn export_state(&self) -> OptimizerState;

    /// Restore a state produced by [`Optimizer::export_state`] on the
    /// same optimizer kind. Errors on a kind mismatch.
    fn import_state(&mut self, st: &OptimizerState) -> anyhow::Result<()>;

    /// Multiply the learning rate by `factor` in place. Used by
    /// divergence recovery (`RecoveryPolicy::lr_shrink`) to take smaller
    /// steps after a rollback. Deliberately *not* part of
    /// [`OptimizerState`], so the shrink survives a state restore.
    fn scale_lr(&mut self, factor: f64);
}

/// Serializable optimizer state (see [`Optimizer::export_state`]).
#[derive(Clone, Debug, PartialEq)]
pub struct OptimizerState {
    /// Optimizer name the state belongs to.
    pub kind: String,
    /// Step counter (Adam's bias-correction `t`; 0 for stateless kinds).
    pub t: u64,
    /// Per-parameter f32 state vectors, grouped by slot.
    pub slots: Vec<Vec<Vec<f32>>>,
}

/// Build an optimizer by config name.
pub fn from_name(
    name: &str,
    adam: AdamParams,
    sgd: SgdParams,
) -> anyhow::Result<Box<dyn Optimizer>> {
    match name {
        "adam" => Ok(Box::new(Adam::new(adam))),
        "sgd" => Ok(Box::new(Sgd::new(sgd.lr))),
        "sgd_momentum" => Ok(Box::new(SgdMomentum::new(sgd.lr, sgd.momentum))),
        other => anyhow::bail!(
            "unknown optimizer '{other}' (expected adam, sgd or sgd_momentum)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_all_kinds() {
        let (a, s) = (AdamParams::default(), SgdParams::default());
        assert_eq!(from_name("adam", a, s).unwrap().name(), "adam");
        assert_eq!(from_name("sgd", a, s).unwrap().name(), "sgd");
        assert_eq!(
            from_name("sgd_momentum", a, s).unwrap().name(),
            "sgd_momentum"
        );
        assert!(from_name("lbfgs", a, s).is_err());
    }
}
