//! Optimizers. The coordinator owns optimizer state (not the HLO graph) —
//! that is what exposes the weight stream to the DMD engine without the
//! extract/assign overhead the paper measured in TensorFlow (their 1.41×).
//!
//! * [`Adam`] — the paper's optimizer.
//! * [`Sgd`] — SGD + momentum (ablation baseline).
//! * [`WeightExtrapolation`] — per-weight line-fit extrapolation, the
//!   related-work baseline (§2, Kamarthi & Pittner style) that DMD is
//!   claimed to beat because per-weight fits "break the coherent
//!   dynamics" — reproduced in `benches/baseline_extrapolation.rs`.

mod adam;
mod extrapolate;
mod sgd;

pub use adam::Adam;
pub use extrapolate::WeightExtrapolation;
pub use sgd::Sgd;

use crate::tensor::Tensor;

/// A first-order optimizer over a flat list of parameter tensors.
pub trait Optimizer {
    /// Apply one update in place. `grads` aligns with `params`.
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]);

    /// Reset internal state (moments, step counter). Called after a DMD
    /// jump when `reset_on_jump` is configured — ablatable: the paper
    /// keeps optimizer state implicit (TF), we default to keeping it.
    fn reset(&mut self);

    fn name(&self) -> &'static str;
}
