//! Adam (Kingma & Ba 2014) — the paper's training optimizer.

use super::{Optimizer, OptimizerState};
use crate::config::AdamParams;
use crate::tensor::Tensor;

/// Adam with per-parameter first/second moments.
pub struct Adam {
    p: AdamParams,
    t: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(p: AdamParams) -> Self {
        Adam {
            p,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }

    pub fn params(&self) -> &AdamParams {
        &self.p
    }

    fn ensure_state(&mut self, params: &[Tensor]) {
        if self.m.len() != params.len() {
            self.m = params.iter().map(|p| vec![0.0; p.len()]).collect();
            self.v = params.iter().map(|p| vec![0.0; p.len()]).collect();
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), grads.len());
        self.ensure_state(params);
        self.t += 1;
        let b1 = self.p.beta1 as f32;
        let b2 = self.p.beta2 as f32;
        // bias-corrected step size
        let bc1 = 1.0 - self.p.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.p.beta2.powi(self.t as i32);
        let alpha = (self.p.lr * bc2.sqrt() / bc1) as f32;
        let eps = self.p.eps as f32;

        for (i, (param, grad)) in params.iter_mut().zip(grads).enumerate() {
            assert_eq!(param.len(), grad.len(), "param/grad shape mismatch at {i}");
            let (ms, vs) = (&mut self.m[i], &mut self.v[i]);
            // stale moments (same tensor count, different widths — e.g.
            // a mismatched import_state) must fail loudly: the lockstep
            // zip below would otherwise silently truncate the update
            assert_eq!(ms.len(), param.len(), "Adam moment/param length mismatch at {i}");
            // `grads` is usually borrowed straight from the session's
            // TrainWorkspace; the update walks all four slices in
            // lockstep (same per-element arithmetic as the indexed loop
            // it replaced, with the bounds checks hoisted)
            for (((p, &g), m), v) in param
                .data_mut()
                .iter_mut()
                .zip(grad.data())
                .zip(ms.iter_mut())
                .zip(vs.iter_mut())
            {
                *m = b1 * *m + (1.0 - b1) * g;
                *v = b2 * *v + (1.0 - b2) * g * g;
                *p -= alpha * *m / (v.sqrt() + eps);
            }
        }
    }

    fn reset(&mut self) {
        self.t = 0;
        for m in &mut self.m {
            m.iter_mut().for_each(|x| *x = 0.0);
        }
        for v in &mut self.v {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    fn name(&self) -> &'static str {
        "adam"
    }

    fn export_state(&self) -> OptimizerState {
        OptimizerState {
            kind: "adam".to_string(),
            t: self.t,
            slots: vec![self.m.clone(), self.v.clone()],
        }
    }

    fn import_state(&mut self, st: &OptimizerState) -> anyhow::Result<()> {
        anyhow::ensure!(st.kind == "adam", "state is for '{}', not adam", st.kind);
        anyhow::ensure!(st.slots.len() == 2, "adam expects 2 state slots (m, v)");
        self.t = st.t;
        self.m = st.slots[0].clone();
        self.v = st.slots[1].clone();
        Ok(())
    }

    fn scale_lr(&mut self, factor: f64) {
        self.p.lr *= factor;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quadratic_grad(params: &[Tensor]) -> Vec<Tensor> {
        // loss = ||p||²/2 → grad = p
        params.to_vec()
    }

    #[test]
    fn converges_on_quadratic() {
        let mut params = vec![Tensor::from_vec(1, 3, vec![5.0, -3.0, 2.0])];
        let mut opt = Adam::new(AdamParams {
            lr: 0.1,
            ..Default::default()
        });
        let initial = params[0].norm();
        for _ in 0..500 {
            let grads = quadratic_grad(&params);
            opt.step(&mut params, &grads);
        }
        assert!(params[0].norm() < 0.01 * initial, "norm {}", params[0].norm());
    }

    #[test]
    fn first_step_size_is_lr() {
        // Adam's bias correction makes the very first update ≈ lr·sign(g).
        let mut params = vec![Tensor::from_vec(1, 2, vec![1.0, 1.0])];
        let grads = vec![Tensor::from_vec(1, 2, vec![0.5, -2.0])];
        let mut opt = Adam::new(AdamParams {
            lr: 0.001,
            ..Default::default()
        });
        opt.step(&mut params, &grads);
        assert!((params[0].get(0, 0) - (1.0 - 0.001)).abs() < 1e-5);
        assert!((params[0].get(0, 1) - (1.0 + 0.001)).abs() < 1e-5);
    }

    #[test]
    fn reset_clears_moments() {
        let mut params = vec![Tensor::from_vec(1, 1, vec![1.0])];
        let grads = vec![Tensor::from_vec(1, 1, vec![1.0])];
        let mut opt = Adam::new(AdamParams::default());
        opt.step(&mut params, &grads);
        opt.reset();
        assert_eq!(opt.t, 0);
        assert_eq!(opt.m[0][0], 0.0);
        assert_eq!(opt.v[0][0], 0.0);
    }

    #[test]
    fn state_roundtrip_is_exact() {
        let grads = vec![Tensor::from_vec(1, 3, vec![0.2, -1.0, 3.0])];
        let mut a = Adam::new(AdamParams::default());
        let mut pa = vec![Tensor::from_vec(1, 3, vec![1.0, 2.0, 3.0])];
        for _ in 0..7 {
            a.step(&mut pa, &grads);
        }
        let st = a.export_state();
        let mut b = Adam::new(AdamParams::default());
        b.import_state(&st).unwrap();
        let mut pb = pa.clone();
        // next steps must be bit-identical (t, m, v all carried)
        for _ in 0..3 {
            a.step(&mut pa, &grads);
            b.step(&mut pb, &grads);
        }
        assert_eq!(pa[0].data(), pb[0].data());
    }

    #[test]
    fn scale_lr_shrinks_the_first_step() {
        // first Adam update ≈ lr·sign(g), so a halved lr halves the move
        let grads = vec![Tensor::from_vec(1, 1, vec![2.0])];
        let mut opt = Adam::new(AdamParams {
            lr: 0.001,
            ..Default::default()
        });
        opt.scale_lr(0.5);
        let mut params = vec![Tensor::from_vec(1, 1, vec![1.0])];
        opt.step(&mut params, &grads);
        assert!((params[0].get(0, 0) - (1.0 - 0.0005)).abs() < 1e-6);
        // the shrink is not part of the exported state: import does not undo it
        let st = opt.export_state();
        opt.import_state(&st).unwrap();
        assert_eq!(opt.params().lr, 0.0005);
    }

    #[test]
    fn multi_tensor_independent_state() {
        let mut params = vec![
            Tensor::from_vec(1, 1, vec![1.0]),
            Tensor::from_vec(1, 1, vec![1.0]),
        ];
        let grads = vec![
            Tensor::from_vec(1, 1, vec![1.0]),
            Tensor::from_vec(1, 1, vec![0.0]),
        ];
        let mut opt = Adam::new(AdamParams::default());
        opt.step(&mut params, &grads);
        assert!(params[0].get(0, 0) < 1.0);
        assert_eq!(params[1].get(0, 0), 1.0); // zero grad → no move
    }
}
