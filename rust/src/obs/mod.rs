//! Crate-wide span tracing: zero-dependency, zero-steady-state-allocation
//! instrumentation drained to Chrome trace-event JSON.
//!
//! # Disarmed fast path
//!
//! Like [`crate::util::failpoint`], the tracer is **disarmed by
//! default** and every instrumented seam pays exactly one relaxed
//! atomic load when it is: [`span`] reads `ARMED` and returns an inert
//! guard without touching the clock, TLS, or the heap. This is what
//! keeps `tests/workspace_alloc.rs` green with tracing compiled into
//! the training hot path — the counting allocator sees zero
//! allocations per step, and the added cost per span site is one
//! `Ordering::Relaxed` load plus a predictable branch.
//!
//! # Armed recording
//!
//! [`arm`] installs a per-thread ring-buffer capacity and flips the
//! armed flag. The first span recorded on each thread allocates that
//! thread's fixed ring once (registered in a global drain list);
//! afterwards recording a span is a clock read plus an uncontended
//! mutex lock and an in-place slot write — **no steady-state
//! allocation even while armed**. When a ring wraps, the oldest spans
//! are overwritten and counted in [`dropped_spans`], so a long run
//! keeps the most recent window instead of growing without bound.
//!
//! # Draining
//!
//! [`drain`] snapshots and clears every thread's ring (sorted by start
//! time); [`write_chrome_trace`] formats a drained snapshot as Chrome
//! trace-event JSON — complete `"X"` events with microsecond
//! timestamps — loadable by `chrome://tracing` and Perfetto, plus one
//! metadata event per thread. `dmdtrain train --trace-out trace.json`
//! arms the tracer around the run and writes the file; `dmdtrain
//! trace` summarizes one back into a per-name wall-time table.
//!
//! Span names must be `&'static str` literals: the ring stores the
//! pointer, never a copy, which is what keeps recording allocation-free.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// Default per-thread ring capacity (spans) when [`arm`] is called
/// without an explicit size.
pub const DEFAULT_RING_CAPACITY: usize = 65_536;

/// Armed flag: 0 = disarmed (the hot-path fast case), otherwise the
/// per-thread ring capacity to install on first touch.
static ARMED: AtomicUsize = AtomicUsize::new(0);

/// Spans overwritten by ring wraparound since the last [`reset`].
static DROPPED: AtomicU64 = AtomicU64::new(0);

/// Monotone id handed to each thread-local ring as its trace `tid`.
static NEXT_TID: AtomicU32 = AtomicU32::new(0);

/// One completed span. `name` is a `&'static str` so recording never
/// copies; `arg` is a free-form numeric payload (batch rows, layer
/// index, task count, …) surfaced as `args.v` in the Chrome JSON.
#[derive(Clone, Copy, Debug)]
pub struct SpanRec {
    pub name: &'static str,
    pub start_ns: u64,
    pub dur_ns: u64,
    pub tid: u32,
    pub arg: u64,
}

struct Ring {
    tid: u32,
    /// Logical ring size. Kept separately from `slots.capacity()`
    /// because `Vec::with_capacity` only guarantees *at least* the
    /// request — the wraparound accounting must be exact.
    cap: usize,
    slots: Vec<SpanRec>,
    /// Next write position; wraps modulo capacity once full.
    head: usize,
}

impl Ring {
    fn record(&mut self, rec: SpanRec) {
        let cap = self.cap;
        if self.slots.len() < cap {
            self.slots.push(rec);
        } else {
            // wraparound: overwrite the oldest slot and count the drop
            self.slots[self.head] = rec;
            DROPPED.fetch_add(1, Ordering::Relaxed);
        }
        self.head = (self.head + 1) % cap;
    }

    /// Spans in chronological order (oldest first), leaving the ring
    /// intact. Once the ring has wrapped, `head` points at the oldest
    /// slot, so the order is `[head..] ++ [..head]`.
    fn snapshot(&self) -> Vec<SpanRec> {
        if self.slots.len() < self.cap {
            return self.slots.clone();
        }
        let mut out = Vec::with_capacity(self.slots.len());
        out.extend_from_slice(&self.slots[self.head..]);
        out.extend_from_slice(&self.slots[..self.head]);
        out
    }

    fn clear(&mut self) {
        self.slots.clear();
        self.head = 0;
    }
}

/// Global list of every thread's ring, for draining from any thread.
fn registry() -> &'static Mutex<Vec<Arc<Mutex<Ring>>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<Mutex<Ring>>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

/// Process-wide trace epoch: all span timestamps are nanoseconds since
/// the first armed span (or [`arm`] call) in the process.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

// Poison-tolerant lock (same discipline as util::failpoint): a panic
// while holding a ring never disables tracing for the rest of the run.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

thread_local! {
    /// This thread's ring, created on first armed span.
    static LOCAL_RING: std::cell::RefCell<Option<Arc<Mutex<Ring>>>> =
        const { std::cell::RefCell::new(None) };
}

/// RAII span guard: inert when the tracer is disarmed at construction
/// (the only cost was one relaxed load), otherwise records
/// `(name, t_start, t_end, tid, arg)` into this thread's ring on drop.
pub struct SpanGuard {
    /// `u64::MAX` marks an inert (disarmed) guard.
    start_ns: u64,
    name: &'static str,
    arg: u64,
}

impl SpanGuard {
    /// Attach/overwrite the numeric payload after construction (e.g.
    /// a row count known only mid-scope).
    pub fn set_arg(&mut self, arg: u64) {
        if self.start_ns != u64::MAX {
            self.arg = arg;
        }
    }
}

impl Drop for SpanGuard {
    #[inline]
    fn drop(&mut self) {
        if self.start_ns == u64::MAX {
            return;
        }
        record_slow(SpanRec {
            name: self.name,
            start_ns: self.start_ns,
            dur_ns: now_ns().saturating_sub(self.start_ns),
            tid: 0, // filled from the ring below
            arg: self.arg,
        });
    }
}

/// Open a span. Disarmed cost: one relaxed atomic load, no clock read,
/// no allocation — safe inside the zero-allocation training hot path.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if ARMED.load(Ordering::Relaxed) == 0 {
        return SpanGuard {
            start_ns: u64::MAX,
            name,
            arg: 0,
        };
    }
    SpanGuard {
        start_ns: now_ns(),
        name,
        arg: 0,
    }
}

/// [`span`] with a numeric payload (rows, layer index, task count, …).
#[inline]
pub fn span_arg(name: &'static str, arg: u64) -> SpanGuard {
    let mut g = span(name);
    g.set_arg(arg);
    g
}

/// True while the tracer is armed.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed) != 0
}

#[cold]
fn record_slow(mut rec: SpanRec) {
    let cap = ARMED.load(Ordering::Relaxed);
    if cap == 0 {
        // disarmed between construction and drop: drop the span
        return;
    }
    // TLS may be gone during thread teardown; losing that span is fine.
    let _ = LOCAL_RING.try_with(|slot| {
        let mut slot = slot.borrow_mut();
        let ring = slot.get_or_insert_with(|| {
            let ring = Arc::new(Mutex::new(Ring {
                tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
                cap: cap.max(2),
                slots: Vec::with_capacity(cap.max(2)),
                head: 0,
            }));
            lock(registry()).push(Arc::clone(&ring));
            ring
        });
        let mut ring = lock(ring);
        rec.tid = ring.tid;
        ring.record(rec);
    });
}

/// Arm the tracer with [`DEFAULT_RING_CAPACITY`] spans per thread.
pub fn arm() {
    arm_with_capacity(DEFAULT_RING_CAPACITY);
}

/// Arm with an explicit per-thread ring capacity (minimum 2). Rings
/// already created keep their original capacity; `arm` before the run
/// of interest to size them consistently.
pub fn arm_with_capacity(capacity: usize) {
    epoch(); // pin t=0 at arm time, not at the first span
    ARMED.store(capacity.max(2), Ordering::Relaxed);
}

/// Disarm: span sites return to the one-relaxed-load fast path.
/// Recorded spans stay resident until [`drain`] or [`reset`].
pub fn disarm() {
    ARMED.store(0, Ordering::Relaxed);
}

/// Spans lost to ring wraparound since the last [`reset`].
pub fn dropped_spans() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Snapshot and clear every thread's ring. Spans come back sorted by
/// start time across threads.
pub fn drain() -> Vec<SpanRec> {
    let rings = lock(registry());
    let mut out = Vec::new();
    for ring in rings.iter() {
        let mut ring = lock(ring);
        out.extend(ring.snapshot());
        ring.clear();
    }
    out.sort_by_key(|s| s.start_ns);
    out
}

/// Disarm, clear every ring and zero the dropped-span counter — the
/// between-tests / between-runs reset.
pub fn reset() {
    disarm();
    let _ = drain();
    DROPPED.store(0, Ordering::Relaxed);
}

/// Serialize tests that arm the process-global tracer (same pattern as
/// `failpoint::serial_guard`).
pub fn serial_guard() -> MutexGuard<'static, ()> {
    static GUARD: OnceLock<Mutex<()>> = OnceLock::new();
    lock(GUARD.get_or_init(|| Mutex::new(())))
}

/// Format drained spans as Chrome trace-event JSON (the "JSON array
/// format"): one complete `"X"` event per span with microsecond
/// timestamps, preceded by `thread_name` metadata so Perfetto labels
/// the rows. `dropped` (from [`dropped_spans`]) lands in the trailing
/// `otherData` block.
pub fn chrome_trace_json(spans: &[SpanRec], dropped: u64) -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(96 * spans.len() + 256);
    out.push_str("{\"traceEvents\":[");
    let mut tids: Vec<u32> = spans.iter().map(|s| s.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    let mut first = true;
    for tid in &tids {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(
            out,
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\
             \"args\":{{\"name\":\"dmdtrain-{tid}\"}}}}"
        );
    }
    for s in spans {
        if !first {
            out.push(',');
        }
        first = false;
        // Chrome wants microseconds; keep sub-µs precision as a decimal.
        let ts_us = s.start_ns as f64 / 1e3;
        let dur_us = s.dur_ns as f64 / 1e3;
        let _ = write!(
            out,
            "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{ts_us:.3},\"dur\":{dur_us:.3},\
             \"pid\":1,\"tid\":{},\"args\":{{\"v\":{}}}}}",
            escape(s.name),
            s.tid,
            s.arg
        );
    }
    let _ = write!(
        out,
        "],\"displayTimeUnit\":\"ms\",\"otherData\":{{\"producer\":\"dmdtrain\",\
         \"dropped_spans\":{dropped}}}}}"
    );
    out
}

/// Drain the tracer and write the Chrome trace JSON to `path`.
pub fn write_chrome_trace(path: &std::path::Path) -> anyhow::Result<(usize, u64)> {
    let spans = drain();
    let dropped = dropped_spans();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, chrome_trace_json(&spans, dropped))?;
    Ok((spans.len(), dropped))
}

/// Escape a span name for direct embedding in a JSON string literal.
/// Names are static identifiers in practice; this keeps pathological
/// ones well-formed anyway.
fn escape(s: &str) -> String {
    if s.chars().all(|c| c != '"' && c != '\\' && c >= ' ') {
        return s.to_string();
    }
    let mut out = String::with_capacity(s.len() + 4);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if c < ' ' => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_span_is_inert() {
        let _g = serial_guard();
        reset();
        {
            let _s = span("noop");
        }
        assert!(drain().is_empty(), "disarmed spans must not record");
        assert_eq!(dropped_spans(), 0);
    }

    #[test]
    fn armed_span_records_name_and_duration() {
        let _g = serial_guard();
        reset();
        arm_with_capacity(16);
        {
            let mut s = span_arg("unit_test_span", 7);
            s.set_arg(9);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        disarm();
        let spans = drain();
        let rec = spans
            .iter()
            .find(|s| s.name == "unit_test_span")
            .expect("span recorded");
        assert!(rec.dur_ns >= 500_000, "~1ms sleep: {}ns", rec.dur_ns);
        assert_eq!(rec.arg, 9);
        reset();
    }

    #[test]
    fn wraparound_drops_oldest_and_counts() {
        let _g = serial_guard();
        reset();
        arm_with_capacity(4);
        for _ in 0..10 {
            let _s = span("wrap");
        }
        disarm();
        let spans = drain();
        let wraps: Vec<_> = spans.iter().filter(|s| s.name == "wrap").collect();
        assert_eq!(wraps.len(), 4, "ring keeps exactly its capacity");
        assert!(dropped_spans() >= 6, "drops counted: {}", dropped_spans());
        // chronological order preserved across the wrap
        for w in wraps.windows(2) {
            assert!(w[0].start_ns <= w[1].start_ns);
        }
        reset();
    }

    #[test]
    fn chrome_json_is_well_formed() {
        let _g = serial_guard();
        reset();
        arm_with_capacity(64);
        {
            let _a = span_arg("outer", 2);
            let _b = span("inner");
        }
        disarm();
        let spans = drain();
        let json = chrome_trace_json(&spans, dropped_spans());
        let doc = crate::util::jsonl::parse(&json).expect("valid JSON");
        let events = doc
            .get("traceEvents")
            .and_then(crate::util::jsonl::Json::as_arr)
            .expect("traceEvents array");
        let xs: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").and_then(crate::util::jsonl::Json::as_str) == Some("X"))
            .collect();
        assert_eq!(xs.len(), 2);
        for e in &xs {
            assert!(e.get("ts").and_then(crate::util::jsonl::Json::as_f64).is_some());
            assert!(e.get("dur").and_then(crate::util::jsonl::Json::as_f64).is_some());
            assert!(e.get("name").and_then(crate::util::jsonl::Json::as_str).is_some());
        }
        reset();
    }

    #[test]
    fn cross_thread_spans_all_drain() {
        let _g = serial_guard();
        reset();
        arm_with_capacity(64);
        let handles: Vec<_> = (0..3)
            .map(|_| {
                std::thread::spawn(|| {
                    let _s = span("worker_span");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        {
            let _s = span("main_span");
        }
        disarm();
        let spans = drain();
        assert_eq!(spans.iter().filter(|s| s.name == "worker_span").count(), 3);
        assert_eq!(spans.iter().filter(|s| s.name == "main_span").count(), 1);
        reset();
    }
}
