//! `Tensor` — dense row-major f32 matrix (vectors are 1×n or n×1).

use super::idx;

/// Dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Tensor {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(
            data.len(),
            rows * cols,
            "Tensor::from_vec: {}x{} needs {} elements, got {}",
            rows,
            cols,
            rows * cols,
            data.len()
        );
        Tensor { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Tensor { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline(always)]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[idx(r, c, self.cols)]
    }

    #[inline(always)]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[idx(r, c, self.cols)] = v;
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Reinterpret as a different shape with the same element count.
    pub fn reshape(mut self, rows: usize, cols: usize) -> Self {
        assert_eq!(rows * cols, self.data.len());
        self.rows = rows;
        self.cols = cols;
        self
    }

    pub fn transpose(&self) -> Tensor {
        let mut out = Tensor::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[idx(c, r, self.rows)] = self.data[idx(r, c, self.cols)];
            }
        }
        out
    }

    /// self += alpha * other (elementwise, shapes must match).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Mean squared difference vs another tensor of the same shape.
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape(), other.shape());
        if self.data.is_empty() {
            return 0.0;
        }
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            / self.data.len() as f64
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_get_set() {
        let mut t = Tensor::zeros(3, 4);
        t.set(2, 1, 5.0);
        assert_eq!(t.get(2, 1), 5.0);
        assert_eq!(t.get(0, 0), 0.0);
        assert_eq!(t.shape(), (3, 4));
    }

    #[test]
    fn from_fn_row_major_layout() {
        let t = Tensor::from_fn(2, 3, |r, c| (10 * r + c) as f32);
        assert_eq!(t.data(), &[0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
        assert_eq!(t.row(1), &[10.0, 11.0, 12.0]);
    }

    #[test]
    fn transpose_involution() {
        let t = Tensor::from_fn(3, 5, |r, c| (r * 5 + c) as f32);
        assert_eq!(t.transpose().transpose(), t);
        assert_eq!(t.transpose().get(4, 2), t.get(2, 4));
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(1, 3, vec![10.0, 10.0, 10.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[6.0, 7.0, 8.0]);
        a.scale(2.0);
        assert_eq!(a.data(), &[12.0, 14.0, 16.0]);
    }

    #[test]
    fn mse_of_identical_is_zero() {
        let t = Tensor::from_fn(4, 4, |r, c| (r + c) as f32);
        assert_eq!(t.mse(&t), 0.0);
    }

    #[test]
    fn mse_simple() {
        let a = Tensor::from_vec(1, 2, vec![0.0, 0.0]);
        let b = Tensor::from_vec(1, 2, vec![1.0, 3.0]);
        assert!((a.mse(&b) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn norm_pythagoras() {
        let t = Tensor::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((t.norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn from_vec_size_mismatch_panics() {
        Tensor::from_vec(2, 2, vec![1.0]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).reshape(3, 2);
        assert_eq!(t.shape(), (3, 2));
        assert_eq!(t.get(2, 1), 6.0);
    }

    #[test]
    fn finite_detection() {
        let mut t = Tensor::zeros(1, 2);
        assert!(t.is_finite());
        t.set(0, 1, f32::NAN);
        assert!(!t.is_finite());
    }
}
