//! Dense row-major matrices. Two concrete types:
//!
//! * [`Tensor`] — f32, the model/runtime currency (weights, snapshots,
//!   datasets; matches the f32 HLO calling convention).
//! * [`Mat`] — f64, the DMD/linalg currency (Gram matrices, Koopman
//!   operators, eigen-solves) where f32 would lose the small singular
//!   values the paper's 1e-10 filter tolerance needs to see.
//!
//! No external linear-algebra crates are available offline, so this is a
//! from-scratch substrate (DESIGN.md S1).

mod mat;
mod tensor_f32;

pub use mat::Mat;
pub use tensor_f32::Tensor;

/// Row-major index helper shared by both types.
#[inline(always)]
pub(crate) fn idx(row: usize, col: usize, cols: usize) -> usize {
    row * cols + col
}
