//! `Mat` — dense row-major f64 matrix used by the DMD/linear-algebra core.

use super::idx;

/// Dense row-major f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Column vector from a slice.
    pub fn col_vec(v: &[f64]) -> Self {
        Mat::from_vec(v.len(), 1, v.to_vec())
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline(always)]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.data[idx(r, c, self.cols)]
    }

    #[inline(always)]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[idx(r, c, self.cols)] = v;
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[idx(c, r, self.rows)] = self.data[idx(r, c, self.cols)];
            }
        }
        out
    }

    /// Dense matmul (small matrices: DMD operators are at most m×m, m≤20;
    /// the O(n·m²) products against snapshots live in `linalg::gram`).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(
            self.cols, other.rows,
            "matmul: {:?} x {:?}",
            self.shape(),
            other.shape()
        );
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self.get(i, k);
                if aik == 0.0 {
                    continue;
                }
                let orow = k * other.cols;
                let out_row = i * other.cols;
                for j in 0..other.cols {
                    out.data[out_row + j] += aik * other.data[orow + j];
                }
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|r| {
                self.row(r)
                    .iter()
                    .zip(v)
                    .map(|(a, b)| a * b)
                    .sum::<f64>()
            })
            .collect()
    }

    pub fn scale(&mut self, alpha: f64) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_diff(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_is_matmul_identity() {
        let a = Mat::from_fn(3, 3, |r, c| (r * 3 + c + 1) as f64);
        let i = Mat::eye(3);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_rectangular() {
        let a = Mat::from_fn(2, 3, |r, c| (r + c) as f64);
        let b = Mat::from_fn(3, 4, |r, c| (r * c) as f64);
        let c = a.matmul(&b);
        assert_eq!(c.shape(), (2, 4));
        // c[1][2] = sum_k a[1][k] * b[k][2] = 1*0 + 2*2 + 3*4 = 16
        assert_eq!(c.get(1, 2), 16.0);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Mat::from_fn(3, 3, |r, c| (r * 3 + c) as f64);
        let v = vec![1.0, -1.0, 2.0];
        let got = a.matvec(&v);
        let want = a.matmul(&Mat::col_vec(&v));
        assert_eq!(got, want.col(0));
    }

    #[test]
    fn transpose_shape_and_values() {
        let a = Mat::from_fn(2, 4, |r, c| (10 * r + c) as f64);
        let t = a.transpose();
        assert_eq!(t.shape(), (4, 2));
        assert_eq!(t.get(3, 1), a.get(1, 3));
    }

    #[test]
    fn frobenius_norm() {
        let a = Mat::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.frobenius() - 5.0).abs() < 1e-14);
    }
}
