//! Typed experiment configurations built from [`Config`] + CLI overrides.

use super::toml::Config;

/// How DMD mode amplitudes `b` are computed from the last snapshot.
///
/// The paper writes `b = Φᵀ w` (eq. 5), but the transpose projection is
/// only well-normalized when the Koopman eigenvector matrix `Y` is close
/// to unitary; on early-training weight ramps (near-defective λ ≈ 1
/// modes) it mis-scales the amplitudes and the λ^s extrapolation
/// explodes — measured in `benches/ablation_filter.rs`. `Pinv` is the
/// standard DMD amplitude `b = Φ⁺ w` (least squares) and is the default;
/// it reproduces the paper's claimed acceleration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Projection {
    /// Paper-faithful transpose projection.
    Transpose,
    /// Least-squares amplitude fit.
    Pinv,
}

impl Projection {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "transpose" => Ok(Projection::Transpose),
            "pinv" => Ok(Projection::Pinv),
            _ => anyhow::bail!("projection must be 'transpose' or 'pinv', got '{s}'"),
        }
    }
}

/// DMD acceleration hyper-parameters (paper Algorithm 1 inputs).
#[derive(Clone, Debug)]
pub struct DmdParams {
    /// Snapshots per DMD fit (paper: m, chosen 14).
    pub m: usize,
    /// Extrapolation horizon in optimizer steps (paper: s, chosen 55).
    pub s: usize,
    /// Singular-value ratio filter: keep modes with σᵢ/σ₀ > tol
    /// (paper: 1e-10).
    pub filter_tol: f64,
    /// Mode-amplitude projection variant.
    pub projection: Projection,
    /// Clamp |λ| of growing modes to this bound (None = paper-faithful,
    /// no clamping). Ablated in `ablation_filter`.
    pub clamp_growth: Option<f64>,
    /// Safety: skip the DMD update if it would *increase* the training
    /// loss by more than this factor (None = always accept, as the paper
    /// does implicitly).
    pub accept_worse_factor: Option<f64>,
    /// Under-relaxation of the jump: w ← w_m + ω·(w_DMD − w_m), ω ∈ (0,1].
    /// 1.0 = the paper's full jump ("implicitly, the learning rate of DMD
    /// iterations is 1.0"); the paper's conclusion names relaxation as the
    /// fix for late-training degradation.
    pub relaxation: f64,
    /// Re-inject stochastic spread after the jump (paper §4: "include add
    /// a random noise at the end of the DMD iterations… by randomly
    /// sampling the difference between the distributions of weights
    /// obtained after the DMD process and the original one"): adds
    /// N(0, std(w_DMD − w_m)) per layer.
    pub noise_reinject: bool,
}

impl Default for DmdParams {
    fn default() -> Self {
        DmdParams {
            m: 14,
            s: 55,
            filter_tol: 1e-10,
            projection: Projection::Pinv,
            clamp_growth: None,
            accept_worse_factor: None,
            relaxation: 1.0,
            noise_reinject: false,
        }
    }
}

impl DmdParams {
    pub fn from_config(c: &Config) -> anyhow::Result<Self> {
        let d = DmdParams::default();
        let clamp = c.f64_or("dmd.clamp_growth", 0.0);
        let worse = c.f64_or("dmd.accept_worse_factor", 0.0);
        Ok(DmdParams {
            m: c.usize_or("dmd.m", d.m),
            s: c.usize_or("dmd.s", d.s),
            filter_tol: c.f64_or("dmd.filter_tol", d.filter_tol),
            projection: Projection::parse(&c.str_or("dmd.projection", "pinv"))?,
            clamp_growth: (clamp > 0.0).then_some(clamp),
            accept_worse_factor: (worse > 0.0).then_some(worse),
            relaxation: c.f64_or("dmd.relaxation", d.relaxation),
            noise_reinject: c.bool_or("dmd.noise_reinject", d.noise_reinject),
        })
    }
}

/// Which acceleration strategy the training session runs between
/// backprop bursts (the `[accel]` TOML section). The jump strategy is a
/// swappable component, not a fixed loop — see
/// `trainer::accel::Accelerator`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccelKind {
    /// Per-layer DMD extrapolation — the paper's Algorithm 1.
    Dmd,
    /// Per-weight OLS line fit (Kamarthi & Pittner style, the paper's
    /// §2 related-work baseline), sharing the DMD (m, s) cadence.
    LineFit,
    /// No acceleration: plain backprop (the paper's "without DMD").
    None,
}

impl AccelKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "dmd" => Ok(AccelKind::Dmd),
            "linefit" => Ok(AccelKind::LineFit),
            "none" => Ok(AccelKind::None),
            _ => anyhow::bail!("accel.kind must be 'dmd', 'linefit' or 'none', got '{s}'"),
        }
    }
}

/// Adam hyper-parameters (paper uses TF defaults).
#[derive(Clone, Copy, Debug)]
pub struct AdamParams {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

impl Default for AdamParams {
    fn default() -> Self {
        AdamParams {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }
}

impl AdamParams {
    pub fn from_config(c: &Config) -> Self {
        let d = AdamParams::default();
        AdamParams {
            lr: c.f64_or("adam.lr", d.lr),
            beta1: c.f64_or("adam.beta1", d.beta1),
            beta2: c.f64_or("adam.beta2", d.beta2),
            eps: c.f64_or("adam.eps", d.eps),
        }
    }
}

/// SGD hyper-parameters (`[sgd]` section; used by the `sgd` and
/// `sgd_momentum` optimizers).
#[derive(Clone, Copy, Debug)]
pub struct SgdParams {
    pub lr: f64,
    pub momentum: f64,
}

impl Default for SgdParams {
    fn default() -> Self {
        SgdParams {
            lr: 1e-2,
            momentum: 0.9,
        }
    }
}

impl SgdParams {
    pub fn from_config(c: &Config) -> Self {
        let d = SgdParams::default();
        SgdParams {
            lr: c.f64_or("sgd.lr", d.lr),
            momentum: c.f64_or("sgd.momentum", d.momentum),
        }
    }
}

/// Divergence-recovery policy (`[recovery]` section): what a
/// `TrainSession` does when a step produces a non-finite loss instead
/// of aborting the process. See `trainer::session` for the mechanism
/// (rolling last-good state, bounded rollback retries, jump cooldown).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RecoveryPolicy {
    /// Roll back to the last good state on a non-finite loss (false =
    /// legacy behavior: error out immediately).
    pub enabled: bool,
    /// Rollbacks allowed since the last *successful* capture before the
    /// run errors out with diagnostics.
    pub max_retries: usize,
    /// Capture the last-good state every N epochs (the capture costs a
    /// params + optimizer-moments copy, so it is amortized; 1 = every
    /// epoch).
    pub snapshot_every: usize,
    /// Accelerator-jump opportunities to skip after a rollback — the
    /// extrapolated jump is the usual divergence source, so the retry
    /// proceeds on plain backprop first.
    pub jump_cooldown: usize,
    /// Multiply the optimizer learning rate by this on every rollback
    /// (1.0 = keep the step size). Persists for the rest of the run:
    /// the lr is not part of the restored optimizer state.
    pub lr_shrink: f64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy {
            enabled: true,
            max_retries: 3,
            snapshot_every: 10,
            jump_cooldown: 1,
            lr_shrink: 1.0,
        }
    }
}

impl RecoveryPolicy {
    /// The legacy fail-fast behavior (divergence aborts the run).
    pub fn disabled() -> Self {
        RecoveryPolicy {
            enabled: false,
            ..Default::default()
        }
    }

    pub fn from_config(c: &Config) -> anyhow::Result<Self> {
        let d = RecoveryPolicy::default();
        let p = RecoveryPolicy {
            enabled: c.bool_or("recovery.enabled", d.enabled),
            max_retries: c.usize_or("recovery.max_retries", d.max_retries),
            snapshot_every: c.usize_or("recovery.snapshot_every", d.snapshot_every).max(1),
            jump_cooldown: c.usize_or("recovery.jump_cooldown", d.jump_cooldown),
            lr_shrink: c.f64_or("recovery.lr_shrink", d.lr_shrink),
        };
        anyhow::ensure!(
            p.lr_shrink > 0.0 && p.lr_shrink <= 1.0,
            "recovery.lr_shrink must be in (0, 1], got {}",
            p.lr_shrink
        );
        Ok(p)
    }
}

/// Full training-run configuration (one Algorithm-1 execution).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Workload name (`[workload] name` / `--workload`): which registered
    /// training scenario this run belongs to (see `crate::workload`).
    /// Defaults to "adr", the paper's pollutant regression.
    pub workload: String,
    /// Manifest entry base name ("paper", "quickstart", …) selecting the
    /// AOT artifacts `train_step_<name>` / `predict_<name>`.
    pub artifact: String,
    pub epochs: usize,
    pub seed: u64,
    /// Dataset path (written by `dmdtrain datagen`).
    pub dataset: String,
    pub adam: AdamParams,
    pub sgd: SgdParams,
    /// Optimizer name: "adam" (default), "sgd" or "sgd_momentum".
    pub optimizer: String,
    /// Acceleration strategy; `dmd = None` (dmd.enabled = false) always
    /// means no acceleration regardless of this kind.
    pub accel: AccelKind,
    /// None = plain backprop baseline (the paper's "without DMD").
    pub dmd: Option<DmdParams>,
    pub eval_every: usize,
    pub log_every: usize,
    pub out_dir: String,
    /// Stop after this many epochs without train-MSE improvement
    /// (0 = disabled). Implemented by `trainer::observe::EarlyStop`.
    pub early_stop_patience: usize,
    /// Minimum train-MSE improvement that resets the patience counter.
    pub early_stop_min_delta: f64,
    /// Save a parameter checkpoint into `out_dir` every N epochs
    /// (0 = disabled). Implemented by `trainer::observe::CheckpointEvery`.
    pub checkpoint_every: usize,
    /// Stream per-epoch metrics as JSONL to this path (live monitoring).
    pub metrics_jsonl: Option<String>,
    /// Record per-layer weight trajectories (Fig 1) — costs memory.
    pub record_weights: bool,
    /// Evaluate train/test MSE before+after every DMD jump (the Fig 3
    /// relative-improvement metric). Costs 2–4 predict passes per event.
    pub measure_dmd: bool,
    /// Dispatch per-layer DMD solves on scoped threads (paper §3).
    pub parallel_dmd: bool,
    /// Divergence-recovery policy (`[recovery]` section).
    pub recovery: RecoveryPolicy,
}

impl TrainConfig {
    pub fn from_config(c: &Config) -> anyhow::Result<Self> {
        let dmd_enabled = c.bool_or("dmd.enabled", true);
        let metrics_jsonl = c.str_or("train.metrics_jsonl", "");
        Ok(TrainConfig {
            workload: c.str_or("workload.name", "adr"),
            artifact: c.str_or("model.artifact", "paper"),
            epochs: c.usize_or("train.epochs", 3000),
            seed: c.u64_or("train.seed", 0),
            dataset: c.require_str("data.path")?,
            adam: AdamParams::from_config(c),
            sgd: SgdParams::from_config(c),
            optimizer: c.str_or("train.optimizer", "adam"),
            accel: AccelKind::parse(&c.str_or("accel.kind", "dmd"))?,
            dmd: dmd_enabled.then(|| DmdParams::from_config(c)).transpose()?,
            eval_every: c.usize_or("train.eval_every", 10),
            log_every: c.usize_or("train.log_every", 50),
            out_dir: c.str_or("train.out_dir", "runs/train"),
            early_stop_patience: c.usize_or("train.early_stop_patience", 0),
            early_stop_min_delta: c.f64_or("train.early_stop_min_delta", 0.0),
            checkpoint_every: c.usize_or("train.checkpoint_every", 0),
            metrics_jsonl: (!metrics_jsonl.is_empty()).then_some(metrics_jsonl),
            record_weights: c.bool_or("train.record_weights", false),
            measure_dmd: c.bool_or("train.measure_dmd", true),
            parallel_dmd: c.bool_or("train.parallel_dmd", true),
            recovery: RecoveryPolicy::from_config(c)?,
        })
    }
}

/// Data-generation configuration. The field inventory is a superset
/// across workloads: the ADR solver (paper §4/App. 1) reads everything;
/// the rom/blasius workloads reuse the generic knobs (`n_samples`,
/// `n_obs`, `train_frac`, `seed`, `out`, `nx`) and ignore the rest.
#[derive(Clone, Debug)]
pub struct DatagenConfig {
    /// Workload that interprets this config (`[workload] name`).
    pub workload: String,
    /// Structured-grid resolution for the ADR solver.
    pub nx: usize,
    pub ny: usize,
    /// Observation points (paper: 2670).
    pub n_obs: usize,
    /// LHS samples (paper: 1000).
    pub n_samples: usize,
    /// Train fraction (paper: 0.8).
    pub train_frac: f64,
    pub seed: u64,
    pub out: String,
    /// Sampling ranges, paper §4.
    pub k12: (f64, f64),
    pub k3: (f64, f64),
    pub d: (f64, f64),
    pub u0: (f64, f64),
    pub uh: (f64, f64),
    pub uv: (f64, f64),
}

impl Default for DatagenConfig {
    fn default() -> Self {
        DatagenConfig {
            workload: "adr".into(),
            nx: 96,
            ny: 48,
            n_obs: 2670,
            n_samples: 1000,
            train_frac: 0.8,
            seed: 0,
            out: "runs/data/pollutant.dmdt".into(),
            k12: (1.0, 20.0),
            k3: (0.0, 10.0),
            d: (0.01, 0.5),
            u0: (0.01, 2.0),
            uh: (-0.2, 0.2),
            uv: (-0.2, 0.2),
        }
    }
}

impl DatagenConfig {
    pub fn from_config(c: &Config) -> Self {
        let d = DatagenConfig::default();
        let range = |key: &str, dft: (f64, f64)| -> (f64, f64) {
            match c.get(key).and_then(super::toml::Value::as_f64_list) {
                Some(v) if v.len() == 2 => (v[0], v[1]),
                _ => dft,
            }
        };
        DatagenConfig {
            workload: c.str_or("workload.name", &d.workload),
            nx: c.usize_or("pde.nx", d.nx),
            ny: c.usize_or("pde.ny", d.ny),
            n_obs: c.usize_or("data.n_obs", d.n_obs),
            n_samples: c.usize_or("data.n_samples", d.n_samples),
            train_frac: c.f64_or("data.train_frac", d.train_frac),
            seed: c.u64_or("data.seed", d.seed),
            out: c.str_or("data.path", &d.out),
            k12: range("ranges.k12", d.k12),
            k3: range("ranges.k3", d.k3),
            d: range("ranges.d", d.d),
            u0: range("ranges.u0", d.u0),
            uh: range("ranges.uh", d.uh),
            uv: range("ranges.uv", d.uv),
        }
    }
}

/// Inference-server configuration (`[serve]` section + CLI overrides).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub host: String,
    /// TCP port; 0 binds an ephemeral port.
    pub port: u16,
    /// Directory of `<name>.dmdp` checkpoints (+ optional sidecars).
    pub model_dir: String,
    /// Micro-batch coalescing window in microseconds (0 = no batching).
    pub batch_window_us: u64,
    /// Row cap per dispatched predict GEMM.
    pub max_batch_rows: usize,
    /// Max concurrent connection-handler threads.
    pub threads: usize,
    /// Background registry-rescan period in seconds (0 = disabled;
    /// `POST /reload` always works).
    pub reload_secs: u64,
    /// Server-side predict deadline in milliseconds (0 = none). The
    /// `X-Deadline-Ms` request header always applies; when both are set
    /// the tighter budget wins.
    pub request_timeout_ms: u64,
    /// Predict queue bound — submits past this wait `submit_wait_ms`,
    /// then shed with 429.
    pub max_queue_jobs: usize,
    /// Per-model in-flight request cap (0 = unlimited); the 429 guard
    /// against one hot model starving the registry.
    pub per_model_inflight: usize,
    /// Bounded submit wait on a full queue, in milliseconds.
    pub submit_wait_ms: u64,
    /// How long a graceful stop waits for in-flight handlers to finish
    /// before force-closing their connections.
    pub drain_timeout_ms: u64,
    /// Close keep-alive connections idle longer than this; also bounds
    /// how long shutdown waits for a dozing client.
    pub idle_timeout_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            host: "127.0.0.1".to_string(),
            port: 7878,
            model_dir: "runs/models".to_string(),
            batch_window_us: 1_000,
            max_batch_rows: 256,
            threads: 64,
            reload_secs: 2,
            request_timeout_ms: 0,
            max_queue_jobs: 1024,
            per_model_inflight: 0,
            submit_wait_ms: 50,
            drain_timeout_ms: 5_000,
            idle_timeout_ms: 5_000,
        }
    }
}

impl ServeConfig {
    pub fn from_config(c: &Config) -> anyhow::Result<Self> {
        let d = ServeConfig::default();
        let port = c.usize_or("serve.port", d.port as usize);
        anyhow::ensure!(port <= u16::MAX as usize, "serve.port {port} out of range");
        Ok(ServeConfig {
            host: c.str_or("serve.host", &d.host),
            port: port as u16,
            model_dir: c.str_or("serve.model_dir", &d.model_dir),
            batch_window_us: c.u64_or("serve.batch_window_us", d.batch_window_us),
            max_batch_rows: c.usize_or("serve.max_batch_rows", d.max_batch_rows).max(1),
            threads: c.usize_or("serve.threads", d.threads).max(1),
            reload_secs: c.u64_or("serve.reload_secs", d.reload_secs),
            request_timeout_ms: c.u64_or("serve.request_timeout_ms", d.request_timeout_ms),
            max_queue_jobs: c.usize_or("serve.max_queue_jobs", d.max_queue_jobs).max(1),
            per_model_inflight: c.usize_or("serve.per_model_inflight", d.per_model_inflight),
            submit_wait_ms: c.u64_or("serve.submit_wait_ms", d.submit_wait_ms),
            drain_timeout_ms: c.u64_or("serve.drain_timeout_ms", d.drain_timeout_ms),
            idle_timeout_ms: c.u64_or("serve.idle_timeout_ms", d.idle_timeout_ms).max(1),
        })
    }
}

/// Where sweep cells execute (`sweep.isolation`).
///
/// `Thread` is the legacy in-process mode: deterministic, zero spawn
/// overhead, but a panicking or OOM-killed cell takes the whole sweep
/// down with it. `Process` runs every cell in a supervised
/// `dmdtrain sweep-worker` subprocess with per-cell timeout, bounded
/// retries and a durable resume ledger (see `coordinator::supervise`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isolation {
    Thread,
    Process,
}

impl Isolation {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "thread" => Ok(Isolation::Thread),
            "process" => Ok(Isolation::Process),
            _ => anyhow::bail!("sweep.isolation must be 'thread' or 'process', got '{s}'"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Isolation::Thread => "thread",
            Isolation::Process => "process",
        }
    }
}

/// One workload arm of a multi-workload sweep: which scenario to train,
/// on which artifact arch, from which dataset file.
///
/// TOML form is a colon-joined string — `"rom"`,
/// `"rom:quickstart"` or `"rom:quickstart:runs/data/rom.dmdt"` — with
/// omitted parts filled from the workload's registry defaults
/// ([`crate::workload::Workload::default_artifact`] /
/// `default_dataset`). [`WorkloadSpec::to_string`] always emits the
/// fully resolved three-part form, so specs round-trip exactly through
/// `to_worker_config`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorkloadSpec {
    pub workload: String,
    pub artifact: String,
    pub dataset: String,
}

impl WorkloadSpec {
    pub fn parse(s: &str) -> anyhow::Result<WorkloadSpec> {
        let mut parts = s.splitn(3, ':');
        let workload = parts.next().unwrap_or("").trim().to_string();
        anyhow::ensure!(!workload.is_empty(), "empty workload spec '{s}'");
        let w = crate::workload::get(&workload)?;
        let pick = |part: Option<&str>, dft: &str| -> String {
            match part.map(str::trim) {
                Some(p) if !p.is_empty() => p.to_string(),
                _ => dft.to_string(),
            }
        };
        let artifact = pick(parts.next(), w.default_artifact());
        let dataset = pick(parts.next(), w.default_dataset());
        Ok(WorkloadSpec {
            workload,
            artifact,
            dataset,
        })
    }
}

impl std::fmt::Display for WorkloadSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}:{}", self.workload, self.artifact, self.dataset)
    }
}

/// Sensitivity-sweep configuration (Fig 3): grids over m and s, plus the
/// fault-tolerance policy for process-isolated cells.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Explicit workload arms (`sweep.workloads`, a list of
    /// [`WorkloadSpec`] strings). Empty = legacy single-workload mode:
    /// the sweep runs `base`'s workload/artifact/dataset alone.
    pub workloads: Vec<WorkloadSpec>,
    pub m_values: Vec<usize>,
    pub s_values: Vec<usize>,
    pub epochs: usize,
    pub workers: usize,
    /// Per-cell wall-clock timeout in seconds (0 = no timeout). A cell
    /// past its deadline is killed, reaped and retried. Process
    /// isolation only.
    pub timeout_secs: u64,
    /// Retries per cell after a crashed/timed-out/failed attempt; the
    /// cell is marked `failed` (never fatal to the sweep) once
    /// `1 + max_retries` attempts are exhausted. Process isolation only.
    pub max_retries: usize,
    /// Backoff before the first retry in milliseconds, doubled per
    /// further attempt (capped at 60 s).
    pub backoff_ms: u64,
    /// Cell execution mode. Defaults to `thread` (the legacy in-process
    /// behavior); `process` enables supervision + the resume ledger.
    pub isolation: Isolation,
    pub base: TrainConfig,
}

impl SweepConfig {
    pub fn from_config(c: &Config) -> anyhow::Result<Self> {
        let m_values = c
            .get("sweep.m_values")
            .and_then(super::toml::Value::as_usize_list)
            .unwrap_or_else(|| (2..=20).step_by(2).collect());
        let s_values = c
            .get("sweep.s_values")
            .and_then(super::toml::Value::as_usize_list)
            .unwrap_or_else(|| (5..=100).step_by(10).collect());
        let workloads = match c.get("sweep.workloads").and_then(super::toml::Value::as_str_list) {
            Some(specs) => specs
                .iter()
                .map(|s| WorkloadSpec::parse(s))
                .collect::<anyhow::Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        Ok(SweepConfig {
            workloads,
            m_values,
            s_values,
            epochs: c.usize_or("sweep.epochs", 300),
            workers: c.usize_or("sweep.workers", 4),
            timeout_secs: c.u64_or("sweep.timeout_secs", 0),
            max_retries: c.usize_or("sweep.max_retries", 2),
            backoff_ms: c.u64_or("sweep.backoff_ms", 500),
            isolation: Isolation::parse(&c.str_or("sweep.isolation", "thread"))?,
            base: TrainConfig::from_config(c)?,
        })
    }

    /// Serialize the *resolved* sweep configuration (config file + CLI
    /// overrides already folded in) back into a [`Config`] that
    /// [`SweepConfig::from_config`] parses to an identical value — the
    /// contract that makes a `sweep-worker` subprocess cell bit-identical
    /// to the same cell run in-process. Floats round-trip exactly via
    /// `Config::to_toml_string`'s shortest-roundtrip formatting.
    pub fn to_worker_config(&self) -> Config {
        use super::toml::Value;
        let mut c = Config::default();
        let b = &self.base;
        let int = |v: usize| Value::Int(v as i64);
        c.set("workload.name", Value::Str(b.workload.clone()));
        c.set("model.artifact", Value::Str(b.artifact.clone()));
        c.set("data.path", Value::Str(b.dataset.clone()));
        c.set("train.epochs", int(b.epochs));
        c.set("train.seed", Value::Int(b.seed as i64));
        c.set("train.optimizer", Value::Str(b.optimizer.clone()));
        c.set("train.eval_every", int(b.eval_every));
        c.set("train.log_every", int(b.log_every));
        c.set("train.out_dir", Value::Str(b.out_dir.clone()));
        c.set("train.early_stop_patience", int(b.early_stop_patience));
        c.set("train.early_stop_min_delta", Value::Float(b.early_stop_min_delta));
        c.set("train.checkpoint_every", int(b.checkpoint_every));
        if let Some(p) = &b.metrics_jsonl {
            c.set("train.metrics_jsonl", Value::Str(p.clone()));
        }
        c.set("train.record_weights", Value::Bool(b.record_weights));
        c.set("train.measure_dmd", Value::Bool(b.measure_dmd));
        c.set("train.parallel_dmd", Value::Bool(b.parallel_dmd));
        c.set("adam.lr", Value::Float(b.adam.lr));
        c.set("adam.beta1", Value::Float(b.adam.beta1));
        c.set("adam.beta2", Value::Float(b.adam.beta2));
        c.set("adam.eps", Value::Float(b.adam.eps));
        c.set("sgd.lr", Value::Float(b.sgd.lr));
        c.set("sgd.momentum", Value::Float(b.sgd.momentum));
        let accel = match b.accel {
            AccelKind::Dmd => "dmd",
            AccelKind::LineFit => "linefit",
            AccelKind::None => "none",
        };
        c.set("accel.kind", Value::Str(accel.to_string()));
        match &b.dmd {
            Some(d) => {
                c.set("dmd.enabled", Value::Bool(true));
                c.set("dmd.m", int(d.m));
                c.set("dmd.s", int(d.s));
                c.set("dmd.filter_tol", Value::Float(d.filter_tol));
                let proj = match d.projection {
                    Projection::Transpose => "transpose",
                    Projection::Pinv => "pinv",
                };
                c.set("dmd.projection", Value::Str(proj.to_string()));
                // from_config maps <= 0.0 back to None for both options
                c.set("dmd.clamp_growth", Value::Float(d.clamp_growth.unwrap_or(0.0)));
                c.set(
                    "dmd.accept_worse_factor",
                    Value::Float(d.accept_worse_factor.unwrap_or(0.0)),
                );
                c.set("dmd.relaxation", Value::Float(d.relaxation));
                c.set("dmd.noise_reinject", Value::Bool(d.noise_reinject));
            }
            None => c.set("dmd.enabled", Value::Bool(false)),
        }
        c.set("recovery.enabled", Value::Bool(b.recovery.enabled));
        c.set("recovery.max_retries", int(b.recovery.max_retries));
        c.set("recovery.snapshot_every", int(b.recovery.snapshot_every));
        c.set("recovery.jump_cooldown", int(b.recovery.jump_cooldown));
        c.set("recovery.lr_shrink", Value::Float(b.recovery.lr_shrink));
        c.set(
            "sweep.m_values",
            Value::List(self.m_values.iter().map(|&v| int(v)).collect()),
        );
        c.set(
            "sweep.s_values",
            Value::List(self.s_values.iter().map(|&v| int(v)).collect()),
        );
        c.set("sweep.epochs", int(self.epochs));
        c.set("sweep.workers", int(self.workers));
        c.set("sweep.timeout_secs", Value::Int(self.timeout_secs as i64));
        c.set("sweep.max_retries", int(self.max_retries));
        c.set("sweep.backoff_ms", Value::Int(self.backoff_ms as i64));
        c.set("sweep.isolation", Value::Str(self.isolation.as_str().to_string()));
        if !self.workloads.is_empty() {
            c.set(
                "sweep.workloads",
                Value::List(
                    self.workloads
                        .iter()
                        .map(|w| Value::Str(w.to_string()))
                        .collect(),
                ),
            );
        }
        c
    }

    /// The workload arms this sweep actually runs: the explicit
    /// `sweep.workloads` list, or a single arm synthesized from `base`
    /// when none were given (legacy single-workload sweeps).
    pub fn effective_workloads(&self) -> Vec<WorkloadSpec> {
        if self.workloads.is_empty() {
            vec![WorkloadSpec {
                workload: self.base.workload.clone(),
                artifact: self.base.artifact.clone(),
                dataset: self.base.dataset.clone(),
            }]
        } else {
            self.workloads.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TEXT: &str = r#"
[model]
artifact = "paper"
[train]
epochs = 100
seed = 7
[data]
path = "runs/data/test.dmdt"
[dmd]
enabled = true
m = 14
s = 55
projection = "pinv"
clamp_growth = 1.0
[adam]
lr = 0.002
[sweep]
m_values = [2, 6, 10]
s_values = [5, 25]
epochs = 50
"#;

    #[test]
    fn train_config_from_toml() {
        let c = Config::parse(TEXT).unwrap();
        let tc = TrainConfig::from_config(&c).unwrap();
        assert_eq!(tc.artifact, "paper");
        assert_eq!(tc.epochs, 100);
        assert_eq!(tc.seed, 7);
        let dmd = tc.dmd.unwrap();
        assert_eq!((dmd.m, dmd.s), (14, 55));
        assert_eq!(dmd.projection, Projection::Pinv);
        assert_eq!(dmd.clamp_growth, Some(1.0));
        assert_eq!(tc.adam.lr, 0.002);
    }

    #[test]
    fn relaxation_and_noise_parsed() {
        let c = Config::parse(
            "[data]\npath = \"x\"\n[dmd]\nrelaxation = 0.5\nnoise_reinject = true",
        )
        .unwrap();
        let tc = TrainConfig::from_config(&c).unwrap();
        let d = tc.dmd.unwrap();
        assert_eq!(d.relaxation, 0.5);
        assert!(d.noise_reinject);
        // defaults: full jump, no noise (paper's base algorithm)
        let d2 = DmdParams::default();
        assert_eq!(d2.relaxation, 1.0);
        assert!(!d2.noise_reinject);
    }

    #[test]
    fn dmd_disabled_gives_none() {
        let c = Config::parse("[dmd]\nenabled = false\n[data]\npath = \"x\"").unwrap();
        let tc = TrainConfig::from_config(&c).unwrap();
        assert!(tc.dmd.is_none());
    }

    #[test]
    fn accelerator_selectable_from_toml() {
        // default: dmd
        let c = Config::parse("[data]\npath = \"x\"").unwrap();
        assert_eq!(TrainConfig::from_config(&c).unwrap().accel, AccelKind::Dmd);
        for (kind, want) in [
            ("dmd", AccelKind::Dmd),
            ("linefit", AccelKind::LineFit),
            ("none", AccelKind::None),
        ] {
            let text = format!("[data]\npath = \"x\"\n[accel]\nkind = \"{kind}\"");
            let tc = TrainConfig::from_config(&Config::parse(&text).unwrap()).unwrap();
            assert_eq!(tc.accel, want);
        }
        let bad = Config::parse("[data]\npath = \"x\"\n[accel]\nkind = \"koopman\"").unwrap();
        assert!(TrainConfig::from_config(&bad).is_err());
    }

    #[test]
    fn optimizer_and_observer_knobs_parse() {
        let c = Config::parse(
            "[data]\npath = \"x\"\n[train]\noptimizer = \"sgd_momentum\"\n\
             early_stop_patience = 5\nearly_stop_min_delta = 0.001\n\
             checkpoint_every = 10\nmetrics_jsonl = \"runs/m.jsonl\"\n\
             [sgd]\nlr = 0.05\nmomentum = 0.8",
        )
        .unwrap();
        let tc = TrainConfig::from_config(&c).unwrap();
        assert_eq!(tc.optimizer, "sgd_momentum");
        assert_eq!(tc.sgd.lr, 0.05);
        assert_eq!(tc.sgd.momentum, 0.8);
        assert_eq!(tc.early_stop_patience, 5);
        assert_eq!(tc.early_stop_min_delta, 0.001);
        assert_eq!(tc.checkpoint_every, 10);
        assert_eq!(tc.metrics_jsonl.as_deref(), Some("runs/m.jsonl"));
        // defaults
        let d = TrainConfig::from_config(&Config::parse("[data]\npath = \"x\"").unwrap()).unwrap();
        assert_eq!(d.optimizer, "adam");
        assert_eq!(d.early_stop_patience, 0);
        assert_eq!(d.checkpoint_every, 0);
        assert!(d.metrics_jsonl.is_none());
    }

    #[test]
    fn recovery_policy_defaults_and_overrides() {
        let d = TrainConfig::from_config(&Config::parse("[data]\npath = \"x\"").unwrap())
            .unwrap()
            .recovery;
        assert!(d.enabled);
        assert_eq!(d.max_retries, 3);
        assert_eq!(d.snapshot_every, 10);
        assert_eq!(d.jump_cooldown, 1);
        assert_eq!(d.lr_shrink, 1.0);

        let c = Config::parse(
            "[data]\npath = \"x\"\n[recovery]\nenabled = false\nmax_retries = 7\n\
             snapshot_every = 0\njump_cooldown = 3\nlr_shrink = 0.5",
        )
        .unwrap();
        let p = TrainConfig::from_config(&c).unwrap().recovery;
        assert!(!p.enabled);
        assert_eq!(p.max_retries, 7);
        assert_eq!(p.snapshot_every, 1, "snapshot_every clamps to >= 1");
        assert_eq!(p.jump_cooldown, 3);
        assert_eq!(p.lr_shrink, 0.5);

        let bad = Config::parse("[data]\npath = \"x\"\n[recovery]\nlr_shrink = 0.0").unwrap();
        assert!(TrainConfig::from_config(&bad).is_err());
        assert!(!RecoveryPolicy::disabled().enabled);
    }

    #[test]
    fn sweep_config_grids() {
        let c = Config::parse(TEXT).unwrap();
        let sc = SweepConfig::from_config(&c).unwrap();
        assert_eq!(sc.m_values, vec![2, 6, 10]);
        assert_eq!(sc.s_values, vec![5, 25]);
        assert_eq!(sc.epochs, 50);
    }

    #[test]
    fn sweep_fault_knobs_defaults_and_overrides() {
        let sc = SweepConfig::from_config(&Config::parse("[data]\npath = \"x\"").unwrap()).unwrap();
        assert_eq!(sc.timeout_secs, 0, "no timeout by default");
        assert_eq!(sc.max_retries, 2);
        assert_eq!(sc.backoff_ms, 500);
        assert_eq!(sc.isolation, Isolation::Thread, "legacy mode by default");

        let c = Config::parse(
            "[data]\npath = \"x\"\n[sweep]\ntimeout_secs = 120\nmax_retries = 5\n\
             backoff_ms = 50\nisolation = \"process\"",
        )
        .unwrap();
        let sc = SweepConfig::from_config(&c).unwrap();
        assert_eq!(sc.timeout_secs, 120);
        assert_eq!(sc.max_retries, 5);
        assert_eq!(sc.backoff_ms, 50);
        assert_eq!(sc.isolation, Isolation::Process);

        let bad = Config::parse("[data]\npath = \"x\"\n[sweep]\nisolation = \"vm\"").unwrap();
        assert!(SweepConfig::from_config(&bad).is_err());
    }

    #[test]
    fn workload_specs_parse_and_resolve_defaults() {
        // full three-part form passes through untouched
        let full = WorkloadSpec::parse("rom:quickstart:runs/data/r.dmdt").unwrap();
        assert_eq!(full.workload, "rom");
        assert_eq!(full.artifact, "quickstart");
        assert_eq!(full.dataset, "runs/data/r.dmdt");
        assert_eq!(full.to_string(), "rom:quickstart:runs/data/r.dmdt");

        // omitted parts fill from the registry defaults
        let short = WorkloadSpec::parse("blasius").unwrap();
        assert_eq!(short.artifact, "blasius");
        assert_eq!(short.dataset, "runs/data/blasius.dmdt");
        let two = WorkloadSpec::parse("adr:test").unwrap();
        assert_eq!(two.artifact, "test");
        assert_eq!(two.dataset, "runs/data/pollutant.dmdt");

        // display → parse is the identity on resolved specs
        assert_eq!(WorkloadSpec::parse(&short.to_string()).unwrap(), short);

        assert!(WorkloadSpec::parse("").is_err());
        assert!(WorkloadSpec::parse("turbulence").is_err(), "unknown workload");
    }

    #[test]
    fn sweep_workloads_parse_and_default_to_base() {
        let c = Config::parse(
            "[data]\npath = \"x\"\n[sweep]\n\
             workloads = [\"adr:test:a.dmdt\", \"rom\", \"blasius:quickstart\"]",
        )
        .unwrap();
        let sc = SweepConfig::from_config(&c).unwrap();
        assert_eq!(sc.workloads.len(), 3);
        assert_eq!(sc.workloads[0].dataset, "a.dmdt");
        assert_eq!(sc.workloads[1].artifact, "rom");
        assert_eq!(sc.workloads[2].artifact, "quickstart");
        assert_eq!(sc.effective_workloads(), sc.workloads);

        // no sweep.workloads → one arm synthesized from base
        let legacy = SweepConfig::from_config(&Config::parse("[data]\npath = \"x\"").unwrap())
            .unwrap();
        assert!(legacy.workloads.is_empty());
        let arms = legacy.effective_workloads();
        assert_eq!(arms.len(), 1);
        assert_eq!(arms[0].workload, "adr");
        assert_eq!(arms[0].artifact, "paper");
        assert_eq!(arms[0].dataset, "x");

        let bad = Config::parse("[data]\npath = \"x\"\n[sweep]\nworkloads = [\"nope\"]").unwrap();
        assert!(SweepConfig::from_config(&bad).is_err());
    }

    #[test]
    fn worker_config_roundtrips_exactly() {
        // the resolved config must survive serialize → parse → resolve
        // unchanged, including CLI overrides and awkward floats: this is
        // the bit-identity contract between coordinator and sweep-worker
        let mut c = Config::parse(TEXT).unwrap();
        c.set("adam.lr", super::super::toml::Value::Float(0.1 + 0.2));
        c.set(
            "train.metrics_jsonl",
            super::super::toml::Value::Str("runs/m.jsonl".into()),
        );
        c.set("sweep.isolation", super::super::toml::Value::Str("process".into()));
        let sc = SweepConfig::from_config(&c).unwrap();
        let text = sc.to_worker_config().to_toml_string();
        let back = SweepConfig::from_config(&Config::parse(&text).unwrap()).unwrap();
        assert_eq!(format!("{sc:?}"), format!("{back:?}"));

        // dmd-disabled and None-optional fields round-trip too
        let c2 = Config::parse("[data]\npath = \"x\"\n[dmd]\nenabled = false").unwrap();
        let sc2 = SweepConfig::from_config(&c2).unwrap();
        let text2 = sc2.to_worker_config().to_toml_string();
        let back2 = SweepConfig::from_config(&Config::parse(&text2).unwrap()).unwrap();
        assert_eq!(format!("{sc2:?}"), format!("{back2:?}"));
        assert!(back2.base.dmd.is_none());
        assert!(back2.base.metrics_jsonl.is_none());

        // explicit workload arms and a non-default base workload
        // round-trip through the worker config too
        let mut c3 = Config::parse(TEXT).unwrap();
        c3.set("workload.name", super::super::toml::Value::Str("rom".into()));
        c3.set(
            "sweep.workloads",
            super::super::toml::Value::List(vec![
                super::super::toml::Value::Str("rom".into()),
                super::super::toml::Value::Str("blasius:quickstart:b.dmdt".into()),
            ]),
        );
        let sc3 = SweepConfig::from_config(&c3).unwrap();
        assert_eq!(sc3.base.workload, "rom");
        let text3 = sc3.to_worker_config().to_toml_string();
        let back3 = SweepConfig::from_config(&Config::parse(&text3).unwrap()).unwrap();
        assert_eq!(format!("{sc3:?}"), format!("{back3:?}"));
    }

    #[test]
    fn datagen_defaults_match_paper() {
        let c = Config::parse("").unwrap();
        let dg = DatagenConfig::from_config(&c);
        assert_eq!(dg.n_obs, 2670);
        assert_eq!(dg.n_samples, 1000);
        assert_eq!(dg.k12, (1.0, 20.0));
        assert_eq!(dg.uv, (-0.2, 0.2));
    }

    #[test]
    fn missing_dataset_errors() {
        let c = Config::parse("").unwrap();
        assert!(TrainConfig::from_config(&c).is_err());
    }

    #[test]
    fn projection_parse_rejects_unknown() {
        assert!(Projection::parse("fourier").is_err());
    }

    #[test]
    fn serve_config_defaults_and_overrides() {
        let sc = ServeConfig::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(sc.port, 7878);
        assert_eq!(sc.batch_window_us, 1_000);
        assert_eq!(sc.max_batch_rows, 256);
        assert_eq!(sc.reload_secs, 2);
        // robustness knobs default to the pre-knob behavior
        assert_eq!(sc.request_timeout_ms, 0, "no server-side deadline");
        assert_eq!(sc.max_queue_jobs, 1024);
        assert_eq!(sc.per_model_inflight, 0, "budgets off");
        assert_eq!(sc.submit_wait_ms, 50, "historical SUBMIT_WAIT");
        assert_eq!(sc.drain_timeout_ms, 5_000);
        assert_eq!(sc.idle_timeout_ms, 5_000, "historical IDLE_TIMEOUT");

        let c = Config::parse(
            "[serve]\nport = 9000\nmodel_dir = \"runs/ci/models\"\n\
             batch_window_us = 500\nmax_batch_rows = 0\nthreads = 8\nreload_secs = 0\n\
             request_timeout_ms = 250\nmax_queue_jobs = 0\nper_model_inflight = 4\n\
             submit_wait_ms = 5\ndrain_timeout_ms = 1000\nidle_timeout_ms = 300",
        )
        .unwrap();
        let sc = ServeConfig::from_config(&c).unwrap();
        assert_eq!(sc.port, 9000);
        assert_eq!(sc.model_dir, "runs/ci/models");
        assert_eq!(sc.batch_window_us, 500);
        assert_eq!(sc.max_batch_rows, 1, "row cap clamps to >= 1");
        assert_eq!(sc.threads, 8);
        assert_eq!(sc.reload_secs, 0);
        assert_eq!(sc.request_timeout_ms, 250);
        assert_eq!(sc.max_queue_jobs, 1, "queue bound clamps to >= 1");
        assert_eq!(sc.per_model_inflight, 4);
        assert_eq!(sc.submit_wait_ms, 5);
        assert_eq!(sc.drain_timeout_ms, 1000);
        assert_eq!(sc.idle_timeout_ms, 300);

        let bad = Config::parse("[serve]\nport = 70000").unwrap();
        assert!(ServeConfig::from_config(&bad).is_err());
    }
}
