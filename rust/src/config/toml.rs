//! The TOML-subset parser.

use std::collections::BTreeMap;
use std::path::Path;

/// A config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    List(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(v) => Some(*v as f64),
            Value::Float(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(v) if *v >= 0 => Some(*v as usize),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(v) if *v >= 0 => Some(*v as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_usize_list(&self) -> Option<Vec<usize>> {
        match self {
            Value::List(items) => items.iter().map(|v| v.as_usize()).collect(),
            _ => None,
        }
    }

    pub fn as_f64_list(&self) -> Option<Vec<f64>> {
        match self {
            Value::List(items) => items.iter().map(|v| v.as_f64()).collect(),
            _ => None,
        }
    }

    pub fn as_str_list(&self) -> Option<Vec<String>> {
        match self {
            Value::List(items) => items
                .iter()
                .map(|v| v.as_str().map(|s| s.to_string()))
                .collect(),
            _ => None,
        }
    }
}

/// Parsed config: `section.key → Value` (keys before any section header
/// live in the "" section).
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> anyhow::Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let name = inner
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: bad section header", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (key, raw_val) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            let val = parse_value(raw_val.trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            values.insert(full_key, val);
        }
        Ok(Config { values })
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!("config {}: {e}", path.as_ref().display())
        })?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    /// Override/insert a value (CLI flags override config files).
    pub fn set(&mut self, key: &str, value: Value) {
        self.values.insert(key.to_string(), value);
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(Value::as_u64).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn require_str(&self, key: &str) -> anyhow::Result<String> {
        self.get(key)
            .and_then(Value::as_str)
            .map(|s| s.to_string())
            .ok_or_else(|| anyhow::anyhow!("config: missing string key '{key}'"))
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }

    /// Serialize back to TOML text that [`Config::parse`] reads into an
    /// equal value map. Keys are grouped by their section prefix (the
    /// text before the first `.`); bare keys come first. Finite floats
    /// round-trip exactly (shortest-roundtrip `Display`); non-finite
    /// floats are not representable in the subset.
    ///
    /// This is what lets the sweep coordinator hand its *resolved*
    /// configuration (file + CLI overrides already applied) to
    /// `sweep-worker` subprocesses as a plain config file.
    pub fn to_toml_string(&self) -> String {
        use std::fmt::Write as _;
        let mut root: Vec<(&str, &Value)> = Vec::new();
        let mut sections: BTreeMap<&str, Vec<(&str, &Value)>> = BTreeMap::new();
        for (key, value) in &self.values {
            match key.split_once('.') {
                Some((section, rest)) => sections.entry(section).or_default().push((rest, value)),
                None => root.push((key.as_str(), value)),
            }
        }
        let mut out = String::new();
        for (key, value) in root {
            let _ = writeln!(out, "{key} = {}", fmt_value(value));
        }
        for (section, entries) in sections {
            let _ = writeln!(out, "[{section}]");
            for (key, value) in entries {
                let _ = writeln!(out, "{key} = {}", fmt_value(value));
            }
        }
        out
    }
}

fn fmt_value(v: &Value) -> String {
    match v {
        Value::Int(i) => i.to_string(),
        Value::Float(f) => {
            let s = format!("{f}");
            // integral floats display without a '.', which would reparse
            // as Int; as_f64 promotes either way but keep the type stable
            if s.contains(['.', 'e', 'E', 'n', 'i']) {
                s
            } else {
                format!("{s}.0")
            }
        }
        Value::Bool(b) => b.to_string(),
        Value::Str(s) => format!("\"{s}\""),
        Value::List(items) => {
            let parts: Vec<String> = items.iter().map(fmt_value).collect();
            format!("[{}]", parts.join(", "))
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // honor '#' except inside quotes
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(raw: &str) -> anyhow::Result<Value> {
    anyhow::ensure!(!raw.is_empty(), "empty value");
    if let Some(inner) = raw.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::List(items));
    }
    if let Some(inner) = raw.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    match raw {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    anyhow::bail!("cannot parse value '{raw}'")
}

fn split_top_level(s: &str) -> Vec<&str> {
    // split on commas outside quotes (no nested arrays in our subset)
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, ch) in s.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_example() {
        let text = r#"
# experiment config
name = "paper-run"          # inline comment
[model]
arch = [6, 40, 200, 1000, 2670]
batch = 800
[dmd]
enabled = true
m = 14
s = 55
filter_tol = 1e-10
[adam]
lr = 0.001
"#;
        let c = Config::parse(text).unwrap();
        assert_eq!(c.str_or("name", ""), "paper-run");
        assert_eq!(
            c.get("model.arch").unwrap().as_usize_list().unwrap(),
            vec![6, 40, 200, 1000, 2670]
        );
        assert_eq!(c.usize_or("model.batch", 0), 800);
        assert!(c.bool_or("dmd.enabled", false));
        assert_eq!(c.usize_or("dmd.m", 0), 14);
        assert!((c.f64_or("dmd.filter_tol", 0.0) - 1e-10).abs() < 1e-24);
        assert_eq!(c.f64_or("adam.lr", 0.0), 0.001);
    }

    #[test]
    fn string_lists_roundtrip() {
        let c = Config::parse(r#"[sweep]
workloads = ["adr:test:a.dmdt", "rom:rom:b.dmdt"]
empty = []
"#)
        .unwrap();
        assert_eq!(
            c.get("sweep.workloads").unwrap().as_str_list().unwrap(),
            vec!["adr:test:a.dmdt".to_string(), "rom:rom:b.dmdt".to_string()]
        );
        assert_eq!(
            c.get("sweep.empty").unwrap().as_str_list().unwrap(),
            Vec::<String>::new()
        );
        // mixed-type lists are not string lists
        let c2 = Config::parse("x = [1, \"a\"]").unwrap();
        assert!(c2.get("x").unwrap().as_str_list().is_none());
        let round = Config::parse(&c.to_toml_string()).unwrap();
        assert_eq!(
            round.get("sweep.workloads").unwrap(),
            c.get("sweep.workloads").unwrap()
        );
    }

    #[test]
    fn int_promotes_to_f64() {
        let c = Config::parse("x = 5").unwrap();
        assert_eq!(c.f64_or("x", 0.0), 5.0);
    }

    #[test]
    fn overrides() {
        let mut c = Config::parse("[dmd]\nm = 14").unwrap();
        c.set("dmd.m", Value::Int(20));
        assert_eq!(c.usize_or("dmd.m", 0), 20);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("novalue =").is_err());
        assert!(Config::parse("bare line without equals").is_err());
    }

    #[test]
    fn hash_inside_string_kept() {
        let c = Config::parse(r##"path = "runs/#1""##).unwrap();
        assert_eq!(c.str_or("path", ""), "runs/#1");
    }

    #[test]
    fn to_toml_string_roundtrips() {
        let text = r#"
name = "paper-run"
[model]
arch = [6, 40, 200, 1000, 2670]
batch = 800
[dmd]
enabled = true
m = 14
filter_tol = 1e-10
relaxation = 1.0
[adam]
lr = 0.001
[data]
path = "runs/#1/data.dmdt"
"#;
        let c = Config::parse(text).unwrap();
        let round = Config::parse(&c.to_toml_string()).unwrap();
        assert_eq!(c.values, round.values);
        // exact float round-trip, including awkward magnitudes
        let mut c2 = Config::parse("").unwrap();
        for (i, v) in [1e-10, 0.1 + 0.2, 1.0, -3.25e17, f64::MIN_POSITIVE]
            .into_iter()
            .enumerate()
        {
            c2.set(&format!("f.v{i}"), Value::Float(v));
        }
        let round2 = Config::parse(&c2.to_toml_string()).unwrap();
        for i in 0..5 {
            let key = format!("f.v{i}");
            assert_eq!(
                round2.f64_or(&key, f64::NAN).to_bits(),
                c2.f64_or(&key, f64::NAN).to_bits(),
                "float {key} must round-trip bit-exactly"
            );
        }
    }

    #[test]
    fn missing_keys_default() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("nope", 7), 7);
        assert!(c.require_str("nope").is_err());
    }
}
