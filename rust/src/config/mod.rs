//! TOML-subset config parser + typed experiment configs.
//!
//! Supported TOML subset (all the experiment configs need): `[section]`
//! headers, `key = value` with integer / float / bool / string / flat
//! array values, `#` comments. No nested tables, no multi-line values.

mod toml;
mod types;

pub use toml::{Config, Value};
pub use types::{
    AccelKind, AdamParams, DatagenConfig, DmdParams, Isolation, Projection, RecoveryPolicy,
    ServeConfig, SgdParams, SweepConfig, TrainConfig, WorkloadSpec,
};
