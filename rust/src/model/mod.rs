//! Model definition on the Rust side: architecture, Xavier init, and the
//! parameter packing conventions shared with the AOT-lowered HLO.
//!
//! Calling convention (recorded in artifacts/manifest.json and checked by
//! the runtime): the flat parameter list is `w1, b1, …, wL, bL` with `wℓ`
//! of shape (fan_in, fan_out) row-major f32 and `bℓ` of shape (fan_out,).
//!
//! DMD flattening (paper: "flattening the weights for layer ℓ"): one
//! snapshot vector per layer = `[wℓ row-major … , bℓ …]` — weights *and*
//! bias evolve under the same per-layer reduced Koopman operator.

use crate::rng::Rng;
use crate::tensor::Tensor;

/// MLP architecture: layer widths input → output (paper:
/// `[6, 40, 200, 1000, 2670]`, soft-sign hidden activations, linear head).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Arch {
    pub dims: Vec<usize>,
}

impl Arch {
    pub fn new(dims: Vec<usize>) -> anyhow::Result<Self> {
        anyhow::ensure!(dims.len() >= 2, "arch needs ≥ 2 layer widths");
        anyhow::ensure!(dims.iter().all(|&d| d > 0), "zero-width layer");
        Ok(Arch { dims })
    }

    /// The paper's network (§4).
    pub fn paper() -> Self {
        Arch {
            dims: vec![6, 40, 200, 1000, 2670],
        }
    }

    /// Number of weight layers L.
    pub fn num_layers(&self) -> usize {
        self.dims.len() - 1
    }

    pub fn input_dim(&self) -> usize {
        self.dims[0]
    }

    pub fn output_dim(&self) -> usize {
        *self.dims.last().unwrap()
    }

    /// (fan_in, fan_out) of layer ℓ.
    pub fn layer_shape(&self, layer: usize) -> (usize, usize) {
        (self.dims[layer], self.dims[layer + 1])
    }

    /// Flattened per-layer parameter count: fan_in·fan_out + fan_out.
    pub fn layer_param_count(&self, layer: usize) -> usize {
        let (fi, fo) = self.layer_shape(layer);
        fi * fo + fo
    }

    /// Total trainable parameters (paper: ~2.9 M).
    pub fn param_count(&self) -> usize {
        (0..self.num_layers()).map(|l| self.layer_param_count(l)).sum()
    }

    /// Xavier/Glorot-uniform initialization (paper §2), biases zero.
    /// Returns the flat `[w1, b1, …]` tensor list.
    pub fn init_params(&self, rng: &mut Rng) -> Vec<Tensor> {
        let mut params = Vec::with_capacity(2 * self.num_layers());
        for l in 0..self.num_layers() {
            let (fi, fo) = self.layer_shape(l);
            let bound = (6.0 / (fi + fo) as f64).sqrt();
            let w = Tensor::from_fn(fi, fo, |_, _| rng.uniform_in(-bound, bound) as f32);
            params.push(w);
            params.push(Tensor::zeros(1, fo));
        }
        params
    }

    /// Flatten layer ℓ's (w, b) pair into one DMD snapshot vector.
    pub fn flatten_layer(&self, params: &[Tensor], layer: usize) -> Vec<f32> {
        let w = &params[2 * layer];
        let b = &params[2 * layer + 1];
        let mut out = Vec::with_capacity(w.len() + b.len());
        out.extend_from_slice(w.data());
        out.extend_from_slice(b.data());
        out
    }

    /// Write a flattened layer vector back into the (w, b) pair.
    pub fn unflatten_layer(&self, params: &mut [Tensor], layer: usize, flat: &[f32]) {
        let (fi, fo) = self.layer_shape(layer);
        assert_eq!(flat.len(), fi * fo + fo, "flat layer size mismatch");
        params[2 * layer]
            .data_mut()
            .copy_from_slice(&flat[..fi * fo]);
        params[2 * layer + 1]
            .data_mut()
            .copy_from_slice(&flat[fi * fo..]);
    }

    /// Expected parameter-tensor shapes, in HLO argument order.
    pub fn param_shapes(&self) -> Vec<(usize, usize)> {
        let mut shapes = Vec::new();
        for l in 0..self.num_layers() {
            let (fi, fo) = self.layer_shape(l);
            shapes.push((fi, fo));
            shapes.push((1, fo));
        }
        shapes
    }
}

/// Pure-Rust forward pass (soft-sign hidden layers, linear head).
///
/// This is the *reference oracle* used by tests and by `predict` when the
/// PJRT runtime is unavailable; the hot path runs the AOT HLO instead.
pub fn forward(arch: &Arch, params: &[Tensor], x: &Tensor) -> Tensor {
    assert_eq!(x.cols(), arch.input_dim());
    let mut h = x.clone();
    for l in 0..arch.num_layers() {
        let w = &params[2 * l];
        let b = &params[2 * l + 1];
        let (fi, fo) = arch.layer_shape(l);
        assert_eq!((w.rows(), w.cols()), (fi, fo));
        let mut z = Tensor::zeros(h.rows(), fo);
        // z = h w + b
        for r in 0..h.rows() {
            let hrow = h.row(r);
            let zrow = z.row_mut(r);
            zrow.copy_from_slice(b.row(0));
            for (k, &hv) in hrow.iter().enumerate() {
                if hv == 0.0 {
                    continue;
                }
                let wrow = w.row(k);
                for (zv, &wv) in zrow.iter_mut().zip(wrow) {
                    *zv += hv * wv;
                }
            }
        }
        if l + 1 < arch.num_layers() {
            for v in z.data_mut() {
                *v /= 1.0 + v.abs(); // soft-sign
            }
        }
        h = z;
    }
    h
}

/// MSE loss matching the L2 graph: mean over batch × outputs.
pub fn mse(pred: &Tensor, target: &Tensor) -> f64 {
    pred.mse(target)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_arch_param_count() {
        let arch = Arch::paper();
        // 6·40+40 + 40·200+200 + 200·1000+1000 + 1000·2670+2670 = 2_882_150
        // (paper: "~2.9 × 10⁶ trainable parameters")
        assert_eq!(arch.param_count(), 2_882_150);
        assert_eq!(arch.num_layers(), 4);
    }

    #[test]
    fn init_shapes_and_bounds() {
        let arch = Arch::new(vec![3, 5, 2]).unwrap();
        let mut rng = Rng::new(0);
        let params = arch.init_params(&mut rng);
        assert_eq!(params.len(), 4);
        assert_eq!(params[0].shape(), (3, 5));
        assert_eq!(params[1].shape(), (1, 5));
        assert_eq!(params[2].shape(), (5, 2));
        let bound = (6.0f64 / 8.0).sqrt() as f32;
        assert!(params[0].data().iter().all(|v| v.abs() <= bound));
        assert!(params[1].data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn flatten_unflatten_roundtrip() {
        let arch = Arch::new(vec![4, 3, 2]).unwrap();
        let mut rng = Rng::new(1);
        let mut params = arch.init_params(&mut rng);
        let flat0 = arch.flatten_layer(&params, 0);
        assert_eq!(flat0.len(), arch.layer_param_count(0));
        let mut modified = flat0.clone();
        for v in &mut modified {
            *v += 1.0;
        }
        arch.unflatten_layer(&mut params, 0, &modified);
        let flat_again = arch.flatten_layer(&params, 0);
        assert_eq!(flat_again, modified);
        // layer 1 untouched
        let f1 = arch.flatten_layer(&params, 1);
        assert_eq!(f1.len(), 3 * 2 + 2);
    }

    #[test]
    fn forward_shapes_and_softsign_bounds() {
        let arch = Arch::new(vec![2, 8, 3]).unwrap();
        let mut rng = Rng::new(2);
        let params = arch.init_params(&mut rng);
        let x = Tensor::from_fn(5, 2, |_, _| rng.normal() as f32);
        let y = forward(&arch, &params, &x);
        assert_eq!(y.shape(), (5, 3));
        assert!(y.is_finite());
    }

    #[test]
    fn forward_known_tiny_network() {
        // 1→1→1: w1=1, b1=0, w2=2, b2=0.5; x=1 → h=softsign(1)=0.5 → y=1.5
        let arch = Arch::new(vec![1, 1, 1]).unwrap();
        let params = vec![
            Tensor::from_vec(1, 1, vec![1.0]),
            Tensor::zeros(1, 1),
            Tensor::from_vec(1, 1, vec![2.0]),
            Tensor::from_vec(1, 1, vec![0.5]),
        ];
        let x = Tensor::from_vec(1, 1, vec![1.0]);
        let y = forward(&arch, &params, &x);
        assert!((y.get(0, 0) - 1.5).abs() < 1e-6);
    }

    #[test]
    fn linear_head_no_activation() {
        // big weights → output exceeds 1 (soft-sign would cap at 1)
        let arch = Arch::new(vec![1, 1]).unwrap();
        let params = vec![
            Tensor::from_vec(1, 1, vec![10.0]),
            Tensor::zeros(1, 1),
        ];
        let x = Tensor::from_vec(1, 1, vec![1.0]);
        let y = forward(&arch, &params, &x);
        assert!((y.get(0, 0) - 10.0).abs() < 1e-6);
    }

    #[test]
    fn arch_validation() {
        assert!(Arch::new(vec![5]).is_err());
        assert!(Arch::new(vec![5, 0, 3]).is_err());
    }
}
