//! Observation-point sampling (paper §4: 2670 points "placed
//! preferentially next to the source and next to the bottom plate").
//!
//! Mixture sampler: 40 % Gaussian cloud around the emission region,
//! 40 % ground-hugging (exponential in y, uniform in x), 20 % uniform
//! background — deterministic given the seed, shared by every sample of
//! the dataset (the DNN's 2670 outputs are *fixed* spatial locations).

use super::adr::{AdrSolution, Grid};
use super::{LX, LY};
use crate::rng::Rng;
use crate::tensor::Tensor;

/// A fixed set of observation points.
#[derive(Clone, Debug)]
pub struct ObservationSet {
    pub points: Vec<(f64, f64)>,
}

impl ObservationSet {
    /// Generate `n` points with the paper's near-source / near-ground
    /// preferential placement.
    pub fn generate(n: usize, seed: u64) -> ObservationSet {
        let mut rng = Rng::new(seed ^ 0x0b5e_44a7_10_55);
        let mut points = Vec::with_capacity(n);
        // emission region centre (between the two source disks)
        let (sx, sy) = (0.1, 0.2);
        while points.len() < n {
            let u = rng.uniform();
            let (x, y) = if u < 0.4 {
                // Gaussian around the source
                (sx + 0.35 * rng.normal().abs(), (sy + 0.25 * rng.normal()).abs())
            } else if u < 0.8 {
                // near-ground layer, exponential height
                (rng.uniform_in(0.0, LX), -0.12 * rng.uniform().max(1e-12).ln())
            } else {
                // uniform background
                (rng.uniform_in(0.0, LX), rng.uniform_in(0.0, LY))
            };
            if (0.0..LX).contains(&x) && (0.0..LY).contains(&y) {
                points.push((x, y));
            }
        }
        ObservationSet { points }
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Sample the pollutant field at every observation point → one row of
    /// the regression target.
    pub fn observe(&self, sol: &AdrSolution) -> Vec<f32> {
        self.points
            .iter()
            .map(|&(x, y)| AdrSolution::sample(&sol.c3, sol.grid, x, y))
            .collect()
    }

    /// Sample an arbitrary field on a grid (used by the Fig-2 dumps).
    pub fn observe_field(&self, field: &Tensor, grid: Grid) -> Vec<f32> {
        self.points
            .iter()
            .map(|&(x, y)| AdrSolution::sample(field, grid, x, y))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_count_in_domain() {
        let obs = ObservationSet::generate(2670, 0);
        assert_eq!(obs.len(), 2670);
        for &(x, y) in &obs.points {
            assert!((0.0..LX).contains(&x));
            assert!((0.0..LY).contains(&y));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = ObservationSet::generate(100, 7);
        let b = ObservationSet::generate(100, 7);
        assert_eq!(a.points, b.points);
        let c = ObservationSet::generate(100, 8);
        assert_ne!(a.points, c.points);
    }

    #[test]
    fn preferential_placement() {
        let obs = ObservationSet::generate(4000, 1);
        let near_ground = obs.points.iter().filter(|&&(_, y)| y < 0.15).count();
        let near_source = obs
            .points
            .iter()
            .filter(|&&(x, y)| (x - 0.1).abs() < 0.4 && (y - 0.2).abs() < 0.4)
            .count();
        // far more density near ground/source than uniform would give
        // (uniform: ground band = 15 %, source box ≈ 10 %)
        assert!(near_ground as f64 > 0.3 * 4000.0, "ground: {near_ground}");
        assert!(near_source as f64 > 0.25 * 4000.0, "source: {near_source}");
    }

    #[test]
    fn observe_length_matches_points() {
        use super::super::adr::{AdrSolver, SampleParams};
        let sol = AdrSolver::new(Grid::new(16, 8), SampleParams::nominal())
            .unwrap()
            .solve()
            .unwrap();
        let obs = ObservationSet::generate(37, 3);
        let row = obs.observe(&sol);
        assert_eq!(row.len(), 37);
        assert!(row.iter().all(|v| v.is_finite()));
    }
}
