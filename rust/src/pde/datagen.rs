//! Dataset generation driver: LHS parameter samples → parallel ADR solves
//! → observation rows → train/test split → on-disk dataset (paper §4).

use super::adr::{AdrSolver, Grid, SampleParams};
use super::observe::ObservationSet;
use crate::config::DatagenConfig;
use crate::data::{latin_hypercube, Dataset};
use crate::rng::Rng;
use crate::tensor::Tensor;

/// Summary of a generation run.
#[derive(Clone, Debug)]
pub struct DatagenReport {
    pub n_train: usize,
    pub n_test: usize,
    pub n_obs: usize,
    pub mean_picard_iters: f64,
    pub wall_secs: f64,
}

/// Generate the pollutant-dispersion dataset and write it to
/// `cfg.out`. Solves are distributed over `workers` OS threads.
pub fn generate_dataset(cfg: &DatagenConfig, workers: usize) -> anyhow::Result<DatagenReport> {
    let t0 = std::time::Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let ranges = [cfg.k12, cfg.k3, cfg.d, cfg.u0, cfg.uh, cfg.uv];
    let samples = latin_hypercube(cfg.n_samples, &ranges, &mut rng);
    let obs = ObservationSet::generate(cfg.n_obs, cfg.seed);
    let grid = Grid::new(cfg.nx, cfg.ny);

    // Parallel solves: static round-robin partition over worker threads.
    let workers = workers.max(1).min(cfg.n_samples);
    let mut rows: Vec<Option<(Vec<f32>, usize)>> = vec![None; cfg.n_samples];
    let errors = std::sync::Mutex::new(Vec::<String>::new());
    {
        let rows_slots: Vec<std::sync::Mutex<&mut Option<(Vec<f32>, usize)>>> =
            rows.iter_mut().map(std::sync::Mutex::new).collect();
        std::thread::scope(|scope| {
            for w in 0..workers {
                let samples = &samples;
                let obs = &obs;
                let rows_slots = &rows_slots;
                let errors = &errors;
                scope.spawn(move || {
                    for idx in (w..samples.len()).step_by(workers) {
                        let run = || -> anyhow::Result<(Vec<f32>, usize)> {
                            let p = SampleParams::from_slice(&samples[idx])?;
                            let sol = AdrSolver::new(grid, p)?.solve()?;
                            Ok((obs.observe(&sol), sol.picard_iters))
                        };
                        match run() {
                            Ok(row) => **rows_slots[idx].lock().unwrap() = Some(row),
                            Err(e) => errors
                                .lock()
                                .unwrap()
                                .push(format!("sample {idx}: {e}")),
                        }
                    }
                });
            }
        });
    }
    let errs = errors.into_inner().unwrap();
    anyhow::ensure!(errs.is_empty(), "datagen failures: {}", errs.join("; "));

    let mut picard_sum = 0usize;
    let mut x_all = Tensor::zeros(cfg.n_samples, 6);
    let mut y_all = Tensor::zeros(cfg.n_samples, cfg.n_obs);
    for (i, slot) in rows.into_iter().enumerate() {
        let (row, iters) = slot.expect("missing row");
        picard_sum += iters;
        for (c, &v) in samples[i].iter().enumerate() {
            x_all.set(i, c, v as f32);
        }
        y_all.row_mut(i).copy_from_slice(&row);
    }

    // shuffled train/test split (paper: 80/20)
    let mut split_rng = Rng::new(cfg.seed ^ 0x5117_5117);
    let perm = split_rng.permutation(cfg.n_samples);
    let n_train = ((cfg.n_samples as f64) * cfg.train_frac).round() as usize;
    let n_test = cfg.n_samples - n_train;
    anyhow::ensure!(n_train > 0 && n_test > 0, "degenerate split");
    let gather = |idx: &[usize], src_x: &Tensor, src_y: &Tensor| {
        let x = Tensor::from_fn(idx.len(), 6, |r, c| src_x.get(idx[r], c));
        let y = Tensor::from_fn(idx.len(), cfg.n_obs, |r, c| src_y.get(idx[r], c));
        (x, y)
    };
    let (x_train, y_train) = gather(&perm[..n_train], &x_all, &y_all);
    let (x_test, y_test) = gather(&perm[n_train..], &x_all, &y_all);

    let ds = Dataset::from_raw(x_train, y_train, x_test, y_test);
    ds.save(&cfg.out)?;

    Ok(DatagenReport {
        n_train,
        n_test,
        n_obs: cfg.n_obs,
        mean_picard_iters: picard_sum as f64 / cfg.n_samples as f64,
        wall_secs: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(out: &str) -> DatagenConfig {
        DatagenConfig {
            nx: 24,
            ny: 12,
            n_obs: 40,
            n_samples: 12,
            train_frac: 0.75,
            seed: 5,
            out: out.into(),
            ..Default::default()
        }
    }

    #[test]
    fn generates_and_roundtrips() {
        let dir = std::env::temp_dir().join("dmdtrain_datagen_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("tiny.dmdt");
        let cfg = tiny_cfg(out.to_str().unwrap());
        let report = generate_dataset(&cfg, 4).unwrap();
        assert_eq!(report.n_train, 9);
        assert_eq!(report.n_test, 3);
        let ds = Dataset::load(&out).unwrap();
        assert_eq!(ds.n_train(), 9);
        assert_eq!(ds.n_test(), 3);
        assert_eq!(ds.n_in(), 6);
        assert_eq!(ds.n_out(), 40);
        // scaled data in the unit box on train
        assert!(ds.x_train.data().iter().all(|v| (-1.0..=1.0).contains(v)));
        assert!(ds.y_train.is_finite() && ds.y_test.is_finite());
    }

    #[test]
    fn deterministic_given_seed() {
        let dir = std::env::temp_dir().join("dmdtrain_datagen_det");
        std::fs::create_dir_all(&dir).unwrap();
        let out_a = dir.join("a.dmdt");
        let out_b = dir.join("b.dmdt");
        generate_dataset(&tiny_cfg(out_a.to_str().unwrap()), 1).unwrap();
        generate_dataset(&tiny_cfg(out_b.to_str().unwrap()), 3).unwrap();
        // different worker counts, identical bytes (static partition is
        // deterministic and solves are independent)
        let a = std::fs::read(&out_a).unwrap();
        let b = std::fs::read(&out_b).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn outputs_vary_across_samples() {
        let dir = std::env::temp_dir().join("dmdtrain_datagen_var");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("v.dmdt");
        generate_dataset(&tiny_cfg(out.to_str().unwrap()), 4).unwrap();
        let ds = Dataset::load(&out).unwrap();
        // the parameter ranges are wide → rows must differ materially
        let r0 = ds.y_train.row(0);
        let r1 = ds.y_train.row(1);
        let diff: f32 = r0.iter().zip(r1).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-3, "rows suspiciously similar: {diff}");
    }
}
