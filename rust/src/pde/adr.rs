//! Steady advection–diffusion–reaction solver for the three solutes
//! (paper eq. 8–9), finite-volume on a structured grid with first-order
//! upwind convection, Picard linearization of the c₁c₂ coupling, and SOR
//! inner solves.
//!
//! System (physical signs — see [`super`] module docs):
//!
//! ```text
//! ū·∇c₁ − D∇²c₁ + K₁₂ c₁ c₂           = Q₁
//! ū·∇c₂ − D∇²c₂ + K₁₂ c₁ c₂           = Q₂
//! ū·∇c₃ − D∇²c₃ + K₃ c₃               = K₁₂ c₁ c₂
//! ```
//!
//! Boundary conditions: inflow (x=0) Dirichlet 0; outflow (x=Lx),
//! terrain (y=0) and top (y=Ly) zero-gradient.

use super::velocity::VelocityField;
use super::{LX, LY};
use crate::tensor::Tensor;

/// The six uncertain parameters of the regression problem (paper §4).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SampleParams {
    pub k12: f64,
    pub k3: f64,
    pub d: f64,
    pub u0: f64,
    pub uh: f64,
    pub uv: f64,
}

impl SampleParams {
    pub fn nominal() -> Self {
        SampleParams {
            k12: 10.0,
            k3: 1.0,
            d: 0.1,
            u0: 1.0,
            uh: 0.0,
            uv: 0.0,
        }
    }

    pub fn from_slice(v: &[f64]) -> anyhow::Result<Self> {
        anyhow::ensure!(v.len() == 6, "need 6 parameters, got {}", v.len());
        Ok(SampleParams {
            k12: v[0],
            k3: v[1],
            d: v[2],
            u0: v[3],
            uh: v[4],
            uv: v[5],
        })
    }

    pub fn to_vec(self) -> Vec<f64> {
        vec![self.k12, self.k3, self.d, self.u0, self.uh, self.uv]
    }
}

/// Cell-centered structured grid over [0, LX] × [0, LY].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Grid {
    pub nx: usize,
    pub ny: usize,
}

impl Grid {
    pub fn new(nx: usize, ny: usize) -> Self {
        assert!(nx >= 4 && ny >= 4, "grid too coarse");
        Grid { nx, ny }
    }

    pub fn dx(&self) -> f64 {
        LX / self.nx as f64
    }

    pub fn dy(&self) -> f64 {
        LY / self.ny as f64
    }

    pub fn x(&self, i: usize) -> f64 {
        (i as f64 + 0.5) * self.dx()
    }

    pub fn y(&self, j: usize) -> f64 {
        (j as f64 + 0.5) * self.dy()
    }

    pub fn cells(&self) -> usize {
        self.nx * self.ny
    }
}

/// Steady solution fields, each (ny, nx) row-major (row = y index).
#[derive(Clone, Debug)]
pub struct AdrSolution {
    pub grid: Grid,
    pub c1: Tensor,
    pub c2: Tensor,
    pub c3: Tensor,
    pub picard_iters: usize,
}

impl AdrSolution {
    /// Bilinear interpolation of a field at physical (x, y).
    pub fn sample(field: &Tensor, grid: Grid, x: f64, y: f64) -> f32 {
        let (dx, dy) = (grid.dx(), grid.dy());
        let fx = ((x / dx) - 0.5).clamp(0.0, (grid.nx - 1) as f64);
        let fy = ((y / dy) - 0.5).clamp(0.0, (grid.ny - 1) as f64);
        let (i0, j0) = (fx as usize, fy as usize);
        let (i1, j1) = ((i0 + 1).min(grid.nx - 1), (j0 + 1).min(grid.ny - 1));
        let (wx, wy) = ((fx - i0 as f64) as f32, (fy - j0 as f64) as f32);
        let v00 = field.get(j0, i0);
        let v10 = field.get(j0, i1);
        let v01 = field.get(j1, i0);
        let v11 = field.get(j1, i1);
        v00 * (1.0 - wx) * (1.0 - wy)
            + v10 * wx * (1.0 - wy)
            + v01 * (1.0 - wx) * wy
            + v11 * wx * wy
    }
}

/// Source terms Q₁/Q₂ (paper eq. 9): emission disks near the chimney.
fn q1(x: f64, y: f64) -> f64 {
    if (x - 0.1).powi(2) + (y - 0.1).powi(2) < 0.25 {
        0.1
    } else {
        0.0
    }
}

fn q2(x: f64, y: f64) -> f64 {
    if (x - 0.1).powi(2) + (y - 0.3).powi(2) < 0.25 {
        0.1
    } else {
        0.0
    }
}

/// The finite-volume ADR solver for one parameter sample.
pub struct AdrSolver {
    pub grid: Grid,
    pub params: SampleParams,
    /// SOR relaxation factor.
    pub omega: f64,
    pub picard_tol: f64,
    pub max_picard: usize,
    pub sor_tol: f64,
    pub max_sor: usize,
    /// Cached cell-centered velocities.
    ux: Vec<f64>,
    uy: Vec<f64>,
}

impl AdrSolver {
    pub fn new(grid: Grid, params: SampleParams) -> anyhow::Result<AdrSolver> {
        let vel = VelocityField::new(params.u0, params.uh, params.uv)?;
        let mut ux = vec![0.0; grid.cells()];
        let mut uy = vec![0.0; grid.cells()];
        for j in 0..grid.ny {
            for i in 0..grid.nx {
                let (x, y) = (grid.x(i), grid.y(j));
                ux[j * grid.nx + i] = vel.ux(x, y);
                uy[j * grid.nx + i] = vel.uy(x, y);
            }
        }
        Ok(AdrSolver {
            grid,
            params,
            // Tolerances sized for training-data accuracy (f32 targets):
            // advection-dominated samples make Gauss–Seidel spectral radius
            // approach 1, so a 1e-9 tolerance would burn the whole sweep
            // budget on stragglers for ~no information gain.
            omega: 1.4,
            picard_tol: 1e-6,
            max_picard: 30,
            sor_tol: 1e-7,
            max_sor: 800,
            ux,
            uy,
        })
    }

    /// Solve one linear ADR equation with reaction field `k(cell)` and
    /// source `rhs(cell)` into `c` (initial guess in, solution out).
    fn solve_linear(&self, k: &[f64], rhs: &[f64], c: &mut [f64]) -> usize {
        let Grid { nx, ny } = self.grid;
        let (dx, dy) = (self.grid.dx(), self.grid.dy());
        let d = self.params.d;
        let (ax_d, ay_d) = (d / (dx * dx), d / (dy * dy));

        for sweep in 0..self.max_sor {
            let mut max_delta = 0.0f64;
            let mut max_c = 1e-30f64;
            for j in 0..ny {
                for i in 0..nx {
                    let idx = j * nx + i;
                    let (u, v) = (self.ux[idx], self.uy[idx]);
                    // upwind convective coefficients
                    let (cw, ce) = (u.max(0.0) / dx, (-u).max(0.0) / dx);
                    let (cs, cn) = (v.max(0.0) / dy, (-v).max(0.0) / dy);

                    // Neighbour contributions (upwind + diffusion). The
                    // diagonal always carries the full convective
                    // throughput |u|/dx + |v|/dy (= cw+ce+cs+cn), so the
                    // matrix stays an M-matrix at every boundary:
                    //  - west i=0: Dirichlet 0 → half-cell diffusion 2D/dx²
                    //  - east/top/terrain: zero-gradient → diffusion drops
                    let mut num = rhs[idx];
                    let mut diag = k[idx].max(0.0);
                    if i > 0 {
                        num += (ax_d + cw) * c[idx - 1];
                        diag += ax_d + cw;
                    } else {
                        diag += 2.0 * ax_d + cw;
                    }
                    if i + 1 < nx {
                        num += (ax_d + ce) * c[idx + 1];
                        diag += ax_d + ce;
                    } else {
                        diag += ce;
                    }
                    if j > 0 {
                        num += (ay_d + cs) * c[idx - nx];
                        diag += ay_d + cs;
                    } else {
                        diag += cs;
                    }
                    if j + 1 < ny {
                        num += (ay_d + cn) * c[idx + nx];
                        diag += ay_d + cn;
                    } else {
                        diag += cn;
                    }

                    let c_gs = num / diag.max(1e-30);
                    let c_new = c[idx] + self.omega * (c_gs - c[idx]);
                    max_delta = max_delta.max((c_new - c[idx]).abs());
                    max_c = max_c.max(c_new.abs());
                    c[idx] = c_new;
                }
            }
            if max_delta < self.sor_tol * max_c {
                return sweep + 1;
            }
        }
        self.max_sor
    }

    /// Run Picard iterations to the steady coupled solution.
    pub fn solve(&self) -> anyhow::Result<AdrSolution> {
        let Grid { nx, ny } = self.grid;
        let cells = self.grid.cells();
        let mut c1 = vec![0.0f64; cells];
        let mut c2 = vec![0.0f64; cells];
        let mut c3 = vec![0.0f64; cells];

        let mut q1v = vec![0.0f64; cells];
        let mut q2v = vec![0.0f64; cells];
        for j in 0..ny {
            for i in 0..nx {
                q1v[j * nx + i] = q1(self.grid.x(i), self.grid.y(j));
                q2v[j * nx + i] = q2(self.grid.x(i), self.grid.y(j));
            }
        }

        let k12 = self.params.k12;
        let mut iters = 0;
        for picard in 0..self.max_picard {
            iters = picard + 1;
            let c1_old = c1.clone();
            let c2_old = c2.clone();

            // c1 with reaction K₁₂·c₂ (Picard-frozen)
            let k_field: Vec<f64> = c2.iter().map(|&c| k12 * c).collect();
            self.solve_linear(&k_field, &q1v, &mut c1);

            // c2 with reaction K₁₂·c₁ (updated c1 — Gauss–Seidel Picard)
            let k_field: Vec<f64> = c1.iter().map(|&c| k12 * c).collect();
            self.solve_linear(&k_field, &q2v, &mut c2);

            let delta: f64 = c1
                .iter()
                .zip(&c1_old)
                .chain(c2.iter().zip(&c2_old))
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            let scale: f64 = c1
                .iter()
                .chain(c2.iter())
                .fold(1e-30, |m, &v| m.max(v.abs()));
            if delta < self.picard_tol * scale {
                break;
            }
        }

        // c3: linear given c1, c2 — production K₁₂c₁c₂, decay K₃
        let k_field = vec![self.params.k3.max(0.0); cells];
        let rhs: Vec<f64> = c1
            .iter()
            .zip(&c2)
            .map(|(&a, &b)| k12 * a * b)
            .collect();
        self.solve_linear(&k_field, &rhs, &mut c3);

        let to_tensor = |v: &[f64]| {
            Tensor::from_vec(ny, nx, v.iter().map(|&x| x as f32).collect())
        };
        let sol = AdrSolution {
            grid: self.grid,
            c1: to_tensor(&c1),
            c2: to_tensor(&c2),
            c3: to_tensor(&c3),
            picard_iters: iters,
        };
        anyhow::ensure!(
            sol.c1.is_finite() && sol.c2.is_finite() && sol.c3.is_finite(),
            "ADR solver produced non-finite fields"
        );
        Ok(sol)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_solver(params: SampleParams) -> AdrSolver {
        AdrSolver::new(Grid::new(32, 16), params).unwrap()
    }

    fn total(field: &Tensor) -> f64 {
        field.data().iter().map(|&v| v as f64).sum()
    }

    #[test]
    fn fields_nonnegative_and_finite() {
        let sol = quick_solver(SampleParams::nominal()).solve().unwrap();
        for f in [&sol.c1, &sol.c2, &sol.c3] {
            assert!(f.is_finite());
            assert!(f.data().iter().all(|&v| v >= -1e-6), "negative concentration");
        }
        assert!(total(&sol.c3) > 0.0, "no pollutant produced");
    }

    #[test]
    fn pollutant_decays_with_k3() {
        let mut p = SampleParams::nominal();
        p.k3 = 0.1;
        let low_decay = quick_solver(p).solve().unwrap();
        p.k3 = 10.0;
        let high_decay = quick_solver(p).solve().unwrap();
        assert!(
            total(&high_decay.c3) < 0.5 * total(&low_decay.c3),
            "K₃ should attenuate the pollutant (Fig 2, panel 2)"
        );
    }

    #[test]
    fn advection_pushes_plume_downstream() {
        let mut p = SampleParams::nominal();
        p.u0 = 0.05;
        let slow = quick_solver(p).solve().unwrap();
        p.u0 = 2.0;
        let fast = quick_solver(p).solve().unwrap();
        // centroid of c1 moves right with stronger wind (Fig 2, panel 4)
        let centroid_x = |sol: &AdrSolution| {
            let mut num = 0.0;
            let mut den = 1e-30;
            for j in 0..sol.grid.ny {
                for i in 0..sol.grid.nx {
                    let v = sol.c1.get(j, i) as f64;
                    num += v * sol.grid.x(i);
                    den += v;
                }
            }
            num / den
        };
        assert!(centroid_x(&fast) > centroid_x(&slow) + 0.05);
    }

    #[test]
    fn diffusion_smooths_the_plume() {
        let mut p = SampleParams::nominal();
        p.d = 0.01;
        let sharp = quick_solver(p).solve().unwrap();
        p.d = 0.5;
        let smooth = quick_solver(p).solve().unwrap();
        // peak-to-mean ratio falls with D (Fig 2, panel 3)
        let peak_ratio = |s: &AdrSolution| {
            let peak = s.c3.data().iter().cloned().fold(0.0f32, f32::max) as f64;
            peak / (total(&s.c3) / s.grid.cells() as f64 + 1e-30)
        };
        assert!(peak_ratio(&sharp) > peak_ratio(&smooth));
    }

    #[test]
    fn k12_concentrates_production_near_source() {
        let mut p = SampleParams::nominal();
        p.k12 = 1.0;
        let weak = quick_solver(p).solve().unwrap();
        p.k12 = 20.0;
        let strong = quick_solver(p).solve().unwrap();
        assert!(
            total(&strong.c3) > total(&weak.c3),
            "faster reaction must produce more pollutant overall"
        );
    }

    #[test]
    fn reactants_consumed_by_reaction() {
        let mut p = SampleParams::nominal();
        p.k12 = 1.0;
        let weak = quick_solver(p).solve().unwrap();
        p.k12 = 20.0;
        let strong = quick_solver(p).solve().unwrap();
        assert!(total(&strong.c1) < total(&weak.c1));
    }

    #[test]
    fn bilinear_sampling_matches_cells() {
        let sol = quick_solver(SampleParams::nominal()).solve().unwrap();
        let g = sol.grid;
        let v = AdrSolution::sample(&sol.c3, g, g.x(5), g.y(7));
        assert!((v - sol.c3.get(7, 5)).abs() < 1e-6);
    }

    #[test]
    fn picard_converges_within_budget() {
        let sol = quick_solver(SampleParams::nominal()).solve().unwrap();
        assert!(sol.picard_iters < 60, "Picard used {}", sol.picard_iters);
    }
}
