//! The pollutant-dispersion PDE substrate (paper §4 + Appendix 1).
//!
//! This is the data generator for the regression problem: a boundary-layer
//! velocity field over terrain (Blasius similarity solution with slip /
//! blowing wall conditions, eqs. 6–7) advecting three reacting solutes
//! (eqs. 8–9) to steady state. 10³ Latin-hypercube parameter samples →
//! 10³ steady c₃ fields, observed at 2670 points.
//!
//! Substitutions vs the paper (documented in DESIGN.md §3):
//! * mixed finite elements → structured finite-volume (5-point stencil,
//!   first-order upwind convection) with Picard + SOR;
//! * the wall conditions f′(0) = u_h/U₀ and f(0) = −2u_v/√(νU₀) are
//!   clamped to the range where the Blasius BVP is well-posed (with
//!   ν = 10⁻⁵ the paper's raw values reach O(10²) where the shooting
//!   problem blows up); the residual slip/blowing velocity is
//!   superposed as an explicit near-wall layer so the ground boundary
//!   condition still holds exactly;
//! * the reaction signs follow the physics (reactants consumed, pollutant
//!   produced by K₁₂c₁c₂ and destroyed by K₃c₃) — the paper's eq. (8) as
//!   printed would make c₃ negative.

mod adr;
mod blasius;
mod datagen;
mod observe;
mod velocity;

pub use adr::{AdrSolution, AdrSolver, Grid, SampleParams};
pub use blasius::{solve_blasius, BlasiusSolution};
pub use datagen::{generate_dataset, DatagenReport};
pub use observe::ObservationSet;
pub use velocity::VelocityField;

/// Kinematic viscosity of air in the paper's non-dimensional setup.
pub const NU: f64 = 1e-5;
/// Domain extent: x ∈ [0, LX], y ∈ [0, LY].
pub const LX: f64 = 2.0;
pub const LY: f64 = 1.0;
/// Virtual origin offset avoiding the x→0 similarity singularity.
pub const X0: f64 = 0.05;
