//! Blasius boundary-layer similarity ODE with slip/blowing wall
//! conditions (paper eq. 7):
//!
//! ```text
//! 2 f''' + f'' f = 0,   f'(0) = u_h/U₀,   f(0) = −2u_v/√(νU₀),
//! f'(η → ∞) = 1
//! ```
//!
//! Solved by RK4 integration + secant shooting on f''(0). The wall values
//! are clamped by the caller ([`super::velocity`]) to the well-posed range.

/// Tabulated similarity solution on a uniform η grid.
#[derive(Clone, Debug)]
pub struct BlasiusSolution {
    pub eta_max: f64,
    pub d_eta: f64,
    /// f(η_i)
    pub f: Vec<f64>,
    /// f'(η_i)
    pub fp: Vec<f64>,
    /// The converged shooting parameter f''(0).
    pub fpp0: f64,
}

impl BlasiusSolution {
    fn lookup(&self, table: &[f64], eta: f64) -> f64 {
        if eta <= 0.0 {
            return table[0];
        }
        let pos = eta / self.d_eta;
        let i = pos as usize;
        if i + 1 >= table.len() {
            // beyond the table: f' = 1, f grows linearly
            let last = table.len() - 1;
            let df = table[last] - table[last - 1];
            return table[last] + df * (pos - last as f64);
        }
        let w = pos - i as f64;
        table[i] * (1.0 - w) + table[i + 1] * w
    }

    /// f(η) with linear extrapolation beyond the table (slope → 1 region).
    pub fn f_at(&self, eta: f64) -> f64 {
        self.lookup(&self.f, eta)
    }

    /// f'(η); clamps to the freestream value beyond the table.
    pub fn fp_at(&self, eta: f64) -> f64 {
        if eta >= self.eta_max {
            return *self.fp.last().unwrap();
        }
        self.lookup(&self.fp, eta)
    }
}

/// RK4 integration of the Blasius system from η=0 to η_max.
/// State = (f, f', f''). Returns the trajectory (f, f') and final f'.
fn integrate(f0: f64, fp0: f64, fpp0: f64, eta_max: f64, d_eta: f64) -> (Vec<f64>, Vec<f64>, f64) {
    let n = (eta_max / d_eta).round() as usize;
    let mut state = [f0, fp0, fpp0];
    let mut f_tab = Vec::with_capacity(n + 1);
    let mut fp_tab = Vec::with_capacity(n + 1);
    f_tab.push(state[0]);
    fp_tab.push(state[1]);
    let deriv = |s: [f64; 3]| [s[1], s[2], -0.5 * s[0] * s[2]];
    for _ in 0..n {
        let k1 = deriv(state);
        let s2 = [
            state[0] + 0.5 * d_eta * k1[0],
            state[1] + 0.5 * d_eta * k1[1],
            state[2] + 0.5 * d_eta * k1[2],
        ];
        let k2 = deriv(s2);
        let s3 = [
            state[0] + 0.5 * d_eta * k2[0],
            state[1] + 0.5 * d_eta * k2[1],
            state[2] + 0.5 * d_eta * k2[2],
        ];
        let k3 = deriv(s3);
        let s4 = [
            state[0] + d_eta * k3[0],
            state[1] + d_eta * k3[1],
            state[2] + d_eta * k3[2],
        ];
        let k4 = deriv(s4);
        for i in 0..3 {
            state[i] += d_eta / 6.0 * (k1[i] + 2.0 * k2[i] + 2.0 * k3[i] + k4[i]);
        }
        // bail out on blow-up, preserving the divergence direction so the
        // shooting bracket keeps a meaningful sign
        if !state.iter().all(|v| v.is_finite()) {
            let last = *fp_tab.last().unwrap();
            return (f_tab, fp_tab, if last >= 1.0 { 1e6 } else { -1e6 });
        }
        if state[1].abs() > 100.0 {
            return (f_tab, fp_tab, state[1].signum() * 1e6);
        }
        f_tab.push(state[0]);
        fp_tab.push(state[1]);
    }
    let final_fp = state[1];
    (f_tab, fp_tab, final_fp)
}

/// Shooting solve: find f''(0) such that f'(η_max) = 1.
///
/// `f0` (blowing) and `fp0` (slip ratio) must be within the well-posed
/// range — callers clamp; see module docs.
pub fn solve_blasius(f0: f64, fp0: f64) -> anyhow::Result<BlasiusSolution> {
    // Shooting is exponentially unstable in η (perturbations grow like
    // e^{∫f/2}); η_max = 9 balances freestream matching against that
    // amplification — beyond ~10 the f''(0) sensitivity exceeds machine
    // precision and bisection can no longer hit the target.
    let eta_max = 9.0;
    let d_eta = 0.01;
    let target = 1.0;

    let shoot = |fpp0: f64| -> f64 {
        let (_, _, final_fp) = integrate(f0, fp0, fpp0, eta_max, d_eta);
        final_fp - target
    };

    // Bracket the root: classical Blasius has f''(0) ≈ 0.4696/√2·…;
    // slip/suction shifts it, but [-5, 5] covers the clamped BC range.
    let (mut a, mut b) = (-5.0f64, 5.0f64);
    let (mut fa, mut fb) = (shoot(a), shoot(b));
    // expand a downward if needed (strong suction cases)
    let mut tries = 0;
    while fa.signum() == fb.signum() && tries < 8 {
        a *= 2.0;
        fa = shoot(a);
        tries += 1;
    }
    anyhow::ensure!(
        fa.signum() != fb.signum(),
        "blasius shooting: no bracket for f0={f0}, fp0={fp0} (fa={fa}, fb={fb})"
    );

    // bisection (robust against the 1e9 overflow plateau) then polish
    for _ in 0..200 {
        let mid = 0.5 * (a + b);
        let fm = shoot(mid);
        if fm == 0.0 || (b - a) < 1e-13 {
            break;
        }
        if fm.signum() == fa.signum() {
            a = mid;
            fa = fm;
        } else {
            b = mid;
            fb = fm;
        }
    }
    let _ = fb;
    let fpp0 = 0.5 * (a + b);
    let (f, fp, final_fp) = integrate(f0, fp0, fpp0, eta_max, d_eta);
    // Strong-blowing profiles approach the freestream slowly and the
    // shooting instability floors the achievable residual; 2e-3 bounds
    // the freestream velocity error at 0.2 % of U₀.
    anyhow::ensure!(
        (final_fp - target).abs() < 2e-3,
        "blasius shooting did not converge: f'({eta_max}) = {final_fp}"
    );
    Ok(BlasiusSolution {
        eta_max,
        d_eta,
        f,
        fp,
        fpp0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classical_blasius_wall_shear() {
        // The paper's ODE "2f''' + f''f = 0" is f''' + ½ f f'' = 0, whose
        // classical no-slip wall shear is f''(0) ≈ 0.332057 (the familiar
        // Blasius constant in this normalization).
        let sol = solve_blasius(0.0, 0.0).unwrap();
        assert!(
            (sol.fpp0 - 0.332057).abs() < 1e-4,
            "f''(0) = {}",
            sol.fpp0
        );
    }

    #[test]
    fn freestream_recovered() {
        let sol = solve_blasius(0.0, 0.0).unwrap();
        assert!((sol.fp_at(8.9) - 1.0).abs() < 2e-3);
        assert!((sol.fp_at(50.0) - 1.0).abs() < 2e-3);
    }

    #[test]
    fn monotone_profile_no_slip() {
        let sol = solve_blasius(0.0, 0.0).unwrap();
        for w in sol.fp.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "f' must be monotone for no-slip");
        }
        assert_eq!(sol.fp[0], 0.0);
    }

    #[test]
    fn slip_wall_condition_honored() {
        let sol = solve_blasius(0.0, 0.5).unwrap();
        assert_eq!(sol.fp[0], 0.5);
        assert!((sol.fp_at(8.5) - 1.0).abs() < 2e-3);
        // slip reduces the velocity deficit → smaller wall shear
        let noslip = solve_blasius(0.0, 0.0).unwrap();
        assert!(sol.fpp0 < noslip.fpp0);
    }

    #[test]
    fn suction_thins_blowing_thickens() {
        let suction = solve_blasius(1.0, 0.0).unwrap(); // f(0) > 0 ⇒ suction
        let blowing = solve_blasius(-1.0, 0.0).unwrap();
        let noslip = solve_blasius(0.0, 0.0).unwrap();
        // wall shear: suction increases it, blowing decreases it
        assert!(suction.fpp0 > noslip.fpp0);
        assert!(blowing.fpp0 < noslip.fpp0);
    }

    #[test]
    fn negative_slip_converges() {
        let sol = solve_blasius(0.0, -0.5).unwrap();
        assert_eq!(sol.fp[0], -0.5);
        assert!((sol.fp_at(sol.eta_max) - 1.0).abs() < 2e-3);
    }

    #[test]
    fn property_profiles_well_posed_across_wall_box() {
        // Property sweep over the well-posed wall-parameter box used by
        // the blasius workload (f0 ∈ [-1.5, 1.5], f'(0) ∈ [-0.9, 0.9]):
        // every profile must honor its wall values, stay monotone in η
        // (zero pressure gradient admits no overshoot) and recover the
        // freestream. The classical corner pins the known constant.
        for i in 0..5 {
            for j in 0..5 {
                let f0 = -1.5 + 3.0 * i as f64 / 4.0;
                let fp0 = -0.9 + 1.8 * j as f64 / 4.0;
                let sol = solve_blasius(f0, fp0)
                    .unwrap_or_else(|e| panic!("f0={f0}, fp0={fp0}: {e}"));
                assert!(
                    (sol.fp[0] - fp0).abs() < 1e-12,
                    "wall slip not honored at f0={f0}, fp0={fp0}"
                );
                assert!(
                    (sol.f[0] - f0).abs() < 1e-12,
                    "wall blowing not honored at f0={f0}, fp0={fp0}"
                );
                for w in sol.fp.windows(2) {
                    assert!(
                        w[1] >= w[0] - 1e-7,
                        "f' not monotone at f0={f0}, fp0={fp0}"
                    );
                }
                assert!(
                    (sol.fp_at(sol.eta_max) - 1.0).abs() < 2e-3,
                    "freestream missed at f0={f0}, fp0={fp0}"
                );
            }
        }
        // classical corner: f''(0) ≈ 0.33206 in this normalization
        let classical = solve_blasius(0.0, 0.0).unwrap();
        assert!((classical.fpp0 - 0.33206).abs() < 1e-4);
    }

    #[test]
    fn f_at_interpolates_linearly_beyond_table() {
        let sol = solve_blasius(0.0, 0.0).unwrap();
        let f10 = sol.f_at(10.0);
        let f12 = sol.f_at(12.0);
        // beyond the boundary layer f grows at slope f' = 1 per unit η
        assert!((f12 - f10 - 2.0).abs() < 1e-2);
    }
}
