//! The background convective velocity field (paper eq. 6 + Appendix 1).
//!
//! Self-similar boundary-layer profile with η = y·√(U₀/(2ν(x+x₀))):
//!
//! ```text
//! u_x(x, y) = U₀ f'(η) + Δ_h e^{−y/δ}
//! u_y(x, y) = ½√(2νU₀/(x+x₀)) (η f'(η) − f(η)) + Δ_v(x) e^{−y/δ}
//! ```
//!
//! where the Blasius wall conditions are clamped to the well-posed range
//! and the residuals Δ_h = u_h − U₀f'(0), Δ_v(x) = u_v/√((x+x₀)/x₀) −
//! u_y,sim(x,0) are superposed as an explicit near-wall layer of width δ
//! so the paper's ground conditions u_x(x,0) = u_h, u_y(x,0) ∝ u_v/√x
//! hold exactly (substitution note in [`super`] module docs).

use super::blasius::{solve_blasius, BlasiusSolution};
use super::{NU, X0};

/// Clamp range for the slip ratio f'(0) = u_h/U₀.
const SLIP_CLAMP: f64 = 0.9;
/// Clamp range for the blowing parameter f(0) = −2u_v/√(νU₀).
const BLOW_CLAMP: f64 = 1.5;
/// Width of the explicit near-wall residual layer.
const WALL_DELTA: f64 = 0.05;

/// Evaluable velocity field for one parameter sample.
#[derive(Clone, Debug)]
pub struct VelocityField {
    u0: f64,
    uh: f64,
    uv: f64,
    sol: BlasiusSolution,
    /// Residual slip velocity carried by the explicit wall layer.
    delta_h: f64,
}

impl VelocityField {
    pub fn new(u0: f64, uh: f64, uv: f64) -> anyhow::Result<VelocityField> {
        anyhow::ensure!(u0 > 0.0, "wind speed U₀ must be positive, got {u0}");
        let slip = (uh / u0).clamp(-SLIP_CLAMP, SLIP_CLAMP);
        let blow = (-2.0 * uv / (NU * u0).sqrt()).clamp(-BLOW_CLAMP, BLOW_CLAMP);
        let sol = solve_blasius(blow, slip)?;
        let delta_h = uh - u0 * slip;
        Ok(VelocityField {
            u0,
            uh,
            uv,
            sol,
            delta_h,
        })
    }

    fn eta(&self, x: f64, y: f64) -> f64 {
        y * (self.u0 / (2.0 * NU * (x + X0))).sqrt()
    }

    /// Similarity part of u_y at (x, y).
    fn uy_sim(&self, x: f64, y: f64) -> f64 {
        let eta = self.eta(x, y);
        let coeff = 0.5 * (2.0 * NU * self.u0 / (x + X0)).sqrt();
        coeff * (eta * self.sol.fp_at(eta) - self.sol.f_at(eta))
    }

    /// Horizontal velocity.
    pub fn ux(&self, x: f64, y: f64) -> f64 {
        let eta = self.eta(x, y);
        self.u0 * self.sol.fp_at(eta) + self.delta_h * (-y / WALL_DELTA).exp()
    }

    /// Vertical velocity.
    pub fn uy(&self, x: f64, y: f64) -> f64 {
        let sim = self.uy_sim(x, y);
        // ground target: u_y(x, 0) = u_v / √((x+x₀)/x₀)  (paper: u_v/√x)
        let target0 = self.uv / ((x + X0) / X0).sqrt();
        let resid = target0 - self.uy_sim(x, 0.0);
        sim + resid * (-y / WALL_DELTA).exp()
    }

    pub fn params(&self) -> (f64, f64, f64) {
        (self.u0, self.uh, self.uv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn freestream_away_from_wall() {
        let v = VelocityField::new(1.0, 0.0, 0.0).unwrap();
        // with ν = 1e-5 the boundary layer is millimetres thick: at
        // y = 0.5 we are far outside it.
        assert!((v.ux(1.0, 0.5) - 1.0).abs() < 1e-3);
    }

    #[test]
    fn wall_slip_condition_exact() {
        for &(u0, uh) in &[(1.0, 0.15), (0.05, 0.2), (2.0, -0.2)] {
            let v = VelocityField::new(u0, uh, 0.0).unwrap();
            assert!(
                (v.ux(0.7, 0.0) - uh).abs() < 1e-9,
                "u_x(x,0) = {} want {uh}",
                v.ux(0.7, 0.0)
            );
        }
    }

    #[test]
    fn wall_blowing_condition_exact() {
        for &(u0, uv) in &[(1.0, 0.1), (0.5, -0.2), (0.01, 0.2)] {
            let v = VelocityField::new(u0, 0.0, uv).unwrap();
            let x = 0.4;
            let want = uv / ((x + X0) / X0).sqrt();
            assert!(
                (v.uy(x, 0.0) - want).abs() < 1e-9,
                "u_y(x,0) = {} want {want}",
                v.uy(x, 0.0)
            );
        }
    }

    #[test]
    fn blowing_decays_downstream() {
        // the u_v/√x ground profile weakens with x
        let v = VelocityField::new(1.0, 0.0, 0.2).unwrap();
        assert!(v.uy(0.1, 0.0) > v.uy(1.0, 0.0));
        assert!(v.uy(1.0, 0.0) > 0.0);
    }

    #[test]
    fn profile_monotone_in_y_no_slip() {
        let v = VelocityField::new(1.5, 0.0, 0.0).unwrap();
        let mut prev = v.ux(1.0, 0.0);
        for k in 1..=20 {
            let y = 0.002 * k as f64;
            let cur = v.ux(1.0, y);
            assert!(cur >= prev - 1e-9, "u_x not monotone at y={y}");
            prev = cur;
        }
    }

    #[test]
    fn mass_flux_sign_of_displacement() {
        // a growing boundary layer displaces flow upward: u_y > 0 above
        // the layer for the no-slip, no-blowing case.
        let v = VelocityField::new(1.0, 0.0, 0.0).unwrap();
        assert!(v.uy(0.5, 0.05) > 0.0);
    }

    #[test]
    fn rejects_nonpositive_wind() {
        assert!(VelocityField::new(0.0, 0.0, 0.0).is_err());
        assert!(VelocityField::new(-1.0, 0.0, 0.0).is_err());
    }

    #[test]
    fn extreme_paper_corner_converges() {
        // U₀ = 0.01, u_h = u_v = ±0.2 — the raw Blasius BCs are O(10²)
        // here; clamping + residual layer must keep this solvable with
        // wall conditions still exact.
        let v = VelocityField::new(0.01, 0.2, -0.2).unwrap();
        assert!((v.ux(1.0, 0.0) - 0.2).abs() < 1e-9);
        assert!(v.ux(1.0, 0.9).is_finite());
    }
}
