"""Layer-1 Pallas kernels for the DMD-accelerated trainer.

Kernels
-------
* ``matmul``       — MXU-tiled f32 matmul (the generic building block).
* ``fused_dense``  — x @ w + b with soft-sign fused in the same kernel, so
                     the pre-activation never round-trips HBM↔VMEM. Exposed
                     through ``jax.custom_vjp`` so ``jax.grad`` works; the
                     backward pass is itself built from Pallas kernels.
* ``linear``       — x @ w + b without activation (output layer), also with
                     a Pallas-backed custom VJP.
* ``softsign_bwd`` — elementwise dz = da / (1 + |z|)², the VJP of soft-sign.
* ``gram``         — sᵀ s for a tall-skinny snapshot matrix, accumulated
                     over row panels in a VMEM scratch output. This is the
                     O(n m²) step of the paper's low-cost SVD.
* ``cross_gram``   — s₋ᵀ s₊, the lag-product needed by the reduced Koopman
                     operator (eq. 3 of the paper).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that the Rust
runtime loads. Tiling decisions still follow TPU VMEM/MXU shapes (128-lane
tiles) so the same kernels are TPU-lowerable; see DESIGN.md
§Hardware-Adaptation.

Inputs with non-tile-multiple shapes are zero-padded to the tile grid and
the result is sliced back; zero padding is exact for every kernel here
(matmul/gram accumulate zeros, elementwise ops are sliced off).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# CPU PJRT cannot run Mosaic custom-calls; interpret mode lowers to plain
# HLO. Keep this True for every pallas_call in the AOT path.
INTERPRET = True

# MXU-friendly tile edge. 128 matches the MXU systolic array; small
# problems fall back to an 8-multiple (f32 sublane) tile.
_TILE = 128
_SUBLANE = 8


def _round_up(value, multiple):
    return ((value + multiple - 1) // multiple) * multiple


def _tile_for(dim):
    """Pick a tile edge: 128 for MXU-sized dims, an 8-multiple otherwise."""
    if dim >= _TILE:
        return _TILE
    return _round_up(dim, _SUBLANE)


def _pad2(a, rows, cols):
    """Zero-pad a 2-D array up to (rows, cols)."""
    pr, pc = rows - a.shape[0], cols - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def matmul(x, w):
    """Tiled Pallas matmul: (M,K) @ (K,N) → (M,N), f32.

    Grid is (M/bm, N/bn); each program reads a full-K row panel of ``x``
    and column panel of ``w`` (K ≤ 2670 in this system, so a (128, K) +
    (K, 128) working set stays well inside a TPU core's VMEM).
    """
    (m, k), (k2, n) = x.shape, w.shape
    assert k == k2, f"matmul inner dims mismatch: {x.shape} @ {w.shape}"
    bm, bn = _tile_for(m), _tile_for(n)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, _SUBLANE)
    xp, wp = _pad2(x, mp, kp), _pad2(w, kp, np_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((kp, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=INTERPRET,
    )(xp, wp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# fused dense (+ soft-sign) with custom VJP
# ---------------------------------------------------------------------------


def _fused_dense_kernel(x_ref, w_ref, b_ref, a_ref, z_ref):
    z = (
        jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )
    z_ref[...] = z
    a_ref[...] = z / (1.0 + jnp.abs(z))


def _fused_dense_pallas(x, w, b):
    """Returns (softsign(x@w+b), x@w+b). The pre-activation is the residual."""
    (m, k), (_, n) = x.shape, w.shape
    bm, bn = _tile_for(m), _tile_for(n)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, _SUBLANE)
    xp, wp = _pad2(x, mp, kp), _pad2(w, kp, np_)
    bp = _pad2(b.reshape(1, -1), 1, np_)
    act, pre = pl.pallas_call(
        _fused_dense_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((kp, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        interpret=INTERPRET,
    )(xp, wp, bp)
    return act[:m, :n], pre[:m, :n]


def _softsign_bwd_kernel(z_ref, da_ref, dz_ref):
    denom = 1.0 + jnp.abs(z_ref[...])
    dz_ref[...] = da_ref[...] / (denom * denom)


def softsign_bwd(z, da):
    """Elementwise VJP of soft-sign: dz = da / (1 + |z|)²."""
    m, n = z.shape
    bm, bn = _tile_for(m), _tile_for(n)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    zp, dap = _pad2(z, mp, np_), _pad2(da, mp, np_)
    out = pl.pallas_call(
        _softsign_bwd_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=INTERPRET,
    )(zp, dap)
    return out[:m, :n]


@jax.custom_vjp
def fused_dense(x, w, b):
    """softsign(x @ w + b) as a single fused Pallas kernel (differentiable)."""
    act, _ = _fused_dense_pallas(x, w, b)
    return act


def _fused_dense_fwd(x, w, b):
    act, pre = _fused_dense_pallas(x, w, b)
    return act, (x, w, pre)


def _fused_dense_bwd(res, da):
    x, w, pre = res
    dz = softsign_bwd(pre, da)
    dx = matmul(dz, w.T)
    dw = matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


fused_dense.defvjp(_fused_dense_fwd, _fused_dense_bwd)


# ---------------------------------------------------------------------------
# linear output layer with custom VJP
# ---------------------------------------------------------------------------


@jax.custom_vjp
def linear(x, w, b):
    """x @ w + b through the Pallas matmul (differentiable, no activation)."""
    return matmul(x, w) + b


def _linear_fwd(x, w, b):
    return matmul(x, w) + b, (x, w)


def _linear_bwd(res, dy):
    x, w = res
    dx = matmul(dy, w.T)
    dw = matmul(x.T, dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


linear.defvjp(_linear_fwd, _linear_bwd)


# ---------------------------------------------------------------------------
# Gram kernels (the paper's O(n m²) low-cost-SVD step)
# ---------------------------------------------------------------------------


def _gram_kernel(s_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    panel = s_ref[...]
    o_ref[...] += jnp.dot(panel.T, panel, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("panel_rows",))
def gram(s, panel_rows=1024):
    """sᵀ s for a tall-skinny (n, m) snapshot matrix.

    The n rows are tiled into VMEM-sized panels; the (m, m) output block is
    revisited by every grid step and used as the accumulator — the Pallas
    expression of the paper's "SVD on the columns" trick (zero row padding
    adds zero to the Gram matrix, so padding is exact).
    """
    n, m = s.shape
    bp = min(panel_rows, _round_up(n, _SUBLANE))
    np_rows = _round_up(n, bp)
    mp = _round_up(m, _SUBLANE)
    sp = _pad2(s, np_rows, mp)
    out = pl.pallas_call(
        _gram_kernel,
        grid=(np_rows // bp,),
        in_specs=[pl.BlockSpec((bp, mp), lambda p: (p, 0))],
        out_specs=pl.BlockSpec((mp, mp), lambda p: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, mp), jnp.float32),
        interpret=INTERPRET,
    )(sp)
    return out[:m, :m]


def _cross_gram_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].T, b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("panel_rows",))
def cross_gram(s_minus, s_plus, panel_rows=1024):
    """s₋ᵀ s₊ for two (n, m) matrices — the DMD lag-product of eq. (3)."""
    assert s_minus.shape[0] == s_plus.shape[0]
    n, ma = s_minus.shape
    _, mb = s_plus.shape
    bp = min(panel_rows, _round_up(n, _SUBLANE))
    np_rows = _round_up(n, bp)
    map_, mbp = _round_up(ma, _SUBLANE), _round_up(mb, _SUBLANE)
    ap, bpd = _pad2(s_minus, np_rows, map_), _pad2(s_plus, np_rows, mbp)
    out = pl.pallas_call(
        _cross_gram_kernel,
        grid=(np_rows // bp,),
        in_specs=[
            pl.BlockSpec((bp, map_), lambda p: (p, 0)),
            pl.BlockSpec((bp, mbp), lambda p: (p, 0)),
        ],
        out_specs=pl.BlockSpec((map_, mbp), lambda p: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((map_, mbp), jnp.float32),
        interpret=INTERPRET,
    )(ap, bpd)
    return out[:ma, :mb]
