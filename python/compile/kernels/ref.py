"""Pure-jnp oracles for every Pallas kernel and for the full model.

These are the correctness ground truth: pytest asserts that each Pallas
kernel (run in interpret mode) matches its oracle to float32 tolerance, and
that the full pallas-backed model matches the jnp-backed model, including
gradients.
"""

import jax.numpy as jnp


def softsign(z):
    """Soft-sign activation: z / (1 + |z|)."""
    return z / (1.0 + jnp.abs(z))


def softsign_grad(z):
    """d softsign / dz = 1 / (1 + |z|)^2."""
    return 1.0 / jnp.square(1.0 + jnp.abs(z))


def matmul(x, w):
    """Plain f32 matmul oracle."""
    return jnp.matmul(x, w)


def dense(x, w, b):
    """Affine layer oracle: x @ w + b."""
    return jnp.matmul(x, w) + b


def fused_dense(x, w, b):
    """Fused affine + soft-sign oracle.

    Returns (activation, pre_activation) — the same pair the Pallas kernel
    produces (the pre-activation is the VJP residual).
    """
    z = jnp.matmul(x, w) + b
    return softsign(z), z


def gram(s):
    """Gram-matrix oracle: sᵀ s for a tall-skinny snapshot matrix."""
    return jnp.matmul(s.T, s)


def cross_gram(s_minus, s_plus):
    """Cross-Gram oracle: s₋ᵀ s₊ — the DMD lag-product."""
    return jnp.matmul(s_minus.T, s_plus)


def mlp_apply(params, x):
    """Full MLP oracle: soft-sign hidden layers, linear output layer.

    ``params`` is a list of (w, b) tuples, ordered input → output.
    """
    h = x
    for w, b in params[:-1]:
        h = softsign(jnp.matmul(h, w) + b)
    w, b = params[-1]
    return jnp.matmul(h, w) + b


def mse_loss(params, x, y):
    """Mean-squared-error loss oracle over the full batch."""
    pred = mlp_apply(params, x)
    return jnp.mean(jnp.square(pred - y))
