"""AOT exporter: lower the L2/L1 graphs once, emit HLO *text* + manifest.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` and
NOT a serialized ``HloModuleProto``: jax ≥ 0.5 emits protos with 64-bit
instruction ids which the Rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``). The HLO text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts land in ``artifacts/`` next to a ``manifest.json`` describing the
exact calling convention (input order, shapes, output arity) that the Rust
runtime (rust/src/runtime/) checks at load time.

Usage:
    python -m compile.aot --out-dir ../artifacts            # build all
    python -m compile.aot --only quickstart,test            # subset
    python -m compile.aot --list                            # show builds
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import fused_dense as K

# ---------------------------------------------------------------------------
# Build matrix.
#
# kernel="pallas": hidden/output layers run the Layer-1 Pallas kernels
#   (interpret-lowered). Used for the quickstart and the Rust integration
#   tests — proves the full L1→L2→L3 composition.
# kernel="jnp": the oracle graph (numerics asserted identical in pytest),
#   which XLA fuses into tight loops. Used for the long paper-scale runs
#   where interpret-mode grid loops would dominate wall time.
# ---------------------------------------------------------------------------

MODEL_BUILDS = [
    # name, arch, batch, kernel
    ("paper", (6, 40, 200, 1000, 2670), 800, "jnp"),
    ("sweep", (6, 40, 200, 267), 800, "jnp"),
    ("quickstart", (6, 16, 32, 64), 64, "pallas"),
    ("test", (6, 8, 6), 16, "pallas"),
    ("test_jnp", (6, 8, 6), 16, "jnp"),
]

GRAM_BUILDS = [
    # name, n (flattened layer size), m (snapshot count)
    ("gram_l2", 8200, 20),
    ("gram_l3", 201000, 14),
]


def to_hlo_text(lowered):
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _export(fn, specs, path):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def _spec_list(specs):
    return [list(map(int, s.shape)) for s in specs]


def _model_entry(name, arch, batch, kernel, out_dir):
    """Export train_step + predict for one (arch, batch, kernel) variant."""
    entries = []
    n_params = 2 * (len(arch) - 1)

    fn, specs = model.train_step_fn(arch, batch, kernel=kernel)
    path = f"train_step_{name}.hlo.txt"
    size = _export(fn, specs, os.path.join(out_dir, path))
    print(f"  train_step_{name}: {size} chars")
    entries.append(
        {
            "name": f"train_step_{name}",
            "kind": "train_step",
            "path": path,
            "arch": list(arch),
            "batch": batch,
            "kernel": kernel,
            "input_shapes": _spec_list(specs),
            # outputs: scalar loss + one gradient per parameter
            "num_outputs": 1 + n_params,
        }
    )

    fn, specs = model.predict_fn(arch, batch, kernel=kernel)
    path = f"predict_{name}.hlo.txt"
    size = _export(fn, specs, os.path.join(out_dir, path))
    print(f"  predict_{name}: {size} chars")
    entries.append(
        {
            "name": f"predict_{name}",
            "kind": "predict",
            "path": path,
            "arch": list(arch),
            "batch": batch,
            "kernel": kernel,
            "input_shapes": _spec_list(specs),
            "num_outputs": 1,
        }
    )
    return entries


def _gram_entry(name, n, m, out_dir):
    """Export the standalone Pallas gram kernel at a concrete (n, m)."""
    spec = jax.ShapeDtypeStruct((n, m), jnp.float32)

    def fn(s):
        return (K.gram(s),)

    path = f"{name}_n{n}_m{m}.hlo.txt"
    size = _export(fn, [spec], os.path.join(out_dir, path))
    print(f"  {name} (n={n}, m={m}): {size} chars")
    return {
        "name": name,
        "kind": "gram",
        "path": path,
        "n": n,
        "m": m,
        "kernel": "pallas",
        "input_shapes": [[n, m]],
        "num_outputs": 1,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="", help="comma-separated build names")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for name, arch, batch, kernel in MODEL_BUILDS:
            print(f"{name}: arch={arch} batch={batch} kernel={kernel}")
        for name, n, m in GRAM_BUILDS:
            print(f"{name}: gram n={n} m={m}")
        return

    only = set(filter(None, args.only.split(",")))
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": 1, "entries": []}

    for name, arch, batch, kernel in MODEL_BUILDS:
        if only and name not in only:
            continue
        print(f"build {name} (arch={arch}, batch={batch}, kernel={kernel})")
        manifest["entries"] += _model_entry(name, arch, batch, kernel, args.out_dir)

    for name, n, m in GRAM_BUILDS:
        if only and name not in only:
            continue
        print(f"build {name}")
        manifest["entries"].append(_gram_entry(name, n, m, args.out_dir))

    man_path = os.path.join(args.out_dir, "manifest.json")
    # Merge with an existing manifest when building a subset.
    if only and os.path.exists(man_path):
        with open(man_path) as f:
            old = json.load(f)
        fresh = {e["name"] for e in manifest["entries"]}
        manifest["entries"] = [
            e for e in old.get("entries", []) if e["name"] not in fresh
        ] + manifest["entries"]
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {man_path} ({len(manifest['entries'])} entries)")


if __name__ == "__main__":
    main()
