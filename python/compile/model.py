"""Layer-2 JAX model: the paper's regression DNN, fwd/bwd.

The network (paper §4): input = 6 uncertain physical parameters
(K₁₂, K₃, D, U₀, u_h, u_v); three soft-sign hidden layers of width
40 / 200 / 1000; linear output layer of width 2670 (one unit per
observation point of the pollutant field); MSE loss; Adam optimizer.

The *optimizer lives in the Rust coordinator* — this module only defines
``predict`` and ``train_step`` (loss + gradients). That split is what gives
the coordinator free access to the weight stream the DMD engine needs
(the paper measured a 1.41× wall-time overhead in TensorFlow, mostly from
weight extract/assign; owning the weights in Rust removes the round-trip).

Two interchangeable backends:
* ``kernel="pallas"`` — hidden/output layers call the Layer-1 Pallas
  kernels (``fused_dense`` / ``linear``), interpret-lowered.
* ``kernel="jnp"``    — the pure-jnp oracle graph, which XLA fuses
  aggressively; used for the long paper-scale training runs.
pytest asserts both produce identical numerics (values and gradients).

Parameter calling convention (shared with the Rust runtime, recorded in
``artifacts/manifest.json``): flat argument list
``w1, b1, w2, b2, …, wL, bL, x[, y]`` with ``w`` of shape (fan_in, fan_out)
row-major f32 and ``b`` of shape (fan_out,).
"""

import jax
import jax.numpy as jnp

from .kernels import fused_dense as K
from .kernels import ref


def init_params(key, arch):
    """Xavier/Glorot-uniform init (paper §2) for ``arch`` layer widths.

    Returns the flat [w1, b1, …, wL, bL] parameter list.
    """
    params = []
    for fan_in, fan_out in zip(arch[:-1], arch[1:]):
        key, wkey = jax.random.split(key)
        bound = jnp.sqrt(6.0 / (fan_in + fan_out))
        w = jax.random.uniform(
            wkey, (fan_in, fan_out), jnp.float32, -bound, bound
        )
        params += [w, jnp.zeros((fan_out,), jnp.float32)]
    return params


def _layers(flat_params):
    """Group the flat [w1, b1, …] list into [(w, b), …] pairs."""
    assert len(flat_params) % 2 == 0
    return list(zip(flat_params[0::2], flat_params[1::2]))


def predict(flat_params, x, kernel="pallas"):
    """Forward pass: soft-sign hidden layers, linear output layer."""
    layers = _layers(flat_params)
    if kernel == "jnp":
        return ref.mlp_apply(layers, x)
    h = x
    for w, b in layers[:-1]:
        h = K.fused_dense(h, w, b)
    w, b = layers[-1]
    return K.linear(h, w, b)


def mse_loss(flat_params, x, y, kernel="pallas"):
    """Mean-squared error over the batch (the paper's loss)."""
    pred = predict(flat_params, x, kernel=kernel)
    return jnp.mean(jnp.square(pred - y))


def train_step(flat_params, x, y, kernel="pallas"):
    """One backpropagation evaluation: returns (loss, [gw1, gb1, …]).

    No optimizer state here — the Rust coordinator applies Adam and owns
    the weight stream (Algorithm 1's snapshot source).
    """
    loss, grads = jax.value_and_grad(
        lambda p: mse_loss(p, x, y, kernel=kernel)
    )(flat_params)
    return (loss, *grads)


def predict_fn(arch, batch, kernel="pallas"):
    """(fn, example_args) pair for AOT-lowering ``predict``."""
    specs = _param_specs(arch) + [
        jax.ShapeDtypeStruct((batch, arch[0]), jnp.float32)
    ]

    def fn(*args):
        return (predict(list(args[:-1]), args[-1], kernel=kernel),)

    return fn, specs


def train_step_fn(arch, batch, kernel="pallas"):
    """(fn, example_args) pair for AOT-lowering ``train_step``."""
    specs = _param_specs(arch) + [
        jax.ShapeDtypeStruct((batch, arch[0]), jnp.float32),
        jax.ShapeDtypeStruct((batch, arch[-1]), jnp.float32),
    ]

    def fn(*args):
        return train_step(list(args[:-2]), args[-2], args[-1], kernel=kernel)

    return fn, specs


def _param_specs(arch):
    specs = []
    for fan_in, fan_out in zip(arch[:-1], arch[1:]):
        specs.append(jax.ShapeDtypeStruct((fan_in, fan_out), jnp.float32))
        specs.append(jax.ShapeDtypeStruct((fan_out,), jnp.float32))
    return specs
