"""L2 model correctness: pallas-backed model vs jnp oracle, shapes, grads."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

ARCHS = st.sampled_from(
    [(4, 8, 6), (6, 16, 32, 64), (3, 5, 7, 9, 11), (2, 4)]
)


def _data(arch, batch, seed=0):
    key = jax.random.PRNGKey(seed)
    kp, kx, ky = jax.random.split(key, 3)
    params = model.init_params(kp, arch)
    x = jax.random.normal(kx, (batch, arch[0]), jnp.float32)
    y = jax.random.normal(ky, (batch, arch[-1]), jnp.float32)
    return params, x, y


class TestInit:
    def test_shapes_and_layout(self):
        arch = (6, 40, 200, 1000, 2670)
        params = model.init_params(jax.random.PRNGKey(0), arch)
        assert len(params) == 8
        for i, (fan_in, fan_out) in enumerate(zip(arch[:-1], arch[1:])):
            assert params[2 * i].shape == (fan_in, fan_out)
            assert params[2 * i + 1].shape == (fan_out,)

    def test_xavier_bound(self):
        arch = (100, 50)
        params = model.init_params(jax.random.PRNGKey(1), arch)
        bound = np.sqrt(6.0 / 150.0)
        w = np.asarray(params[0])
        assert np.all(np.abs(w) <= bound)
        assert np.std(w) > 0.3 * bound  # actually spread out, not collapsed

    def test_param_count_paper_arch(self):
        # paper: "~2.9e6 trainable parameters"
        arch = (6, 40, 200, 1000, 2670)
        params = model.init_params(jax.random.PRNGKey(0), arch)
        total = sum(int(np.prod(p.shape)) for p in params)
        assert abs(total - 2.9e6) / 2.9e6 < 0.05


class TestForward:
    @settings(max_examples=8, deadline=None)
    @given(arch=ARCHS, batch=st.integers(1, 33), seed=st.integers(0, 5))
    def test_pallas_matches_jnp(self, arch, batch, seed):
        params, x, _ = _data(arch, batch, seed)
        got = model.predict(params, x, kernel="pallas")
        want = model.predict(params, x, kernel="jnp")
        assert got.shape == (batch, arch[-1])
        assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_jnp_matches_ref_oracle(self):
        params, x, _ = _data((6, 16, 32, 64), 16)
        got = model.predict(params, x, kernel="jnp")
        pairs = list(zip(params[0::2], params[1::2]))
        want = ref.mlp_apply(pairs, x)
        assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_hidden_activations_bounded(self):
        # soft-sign hidden layers keep intermediate activations in (-1, 1);
        # with small Xavier weights the *output* stays moderate too.
        params, x, _ = _data((6, 16, 32, 64), 16)
        out = model.predict(params, x, kernel="pallas")
        assert np.all(np.isfinite(np.asarray(out)))


class TestTrainStep:
    @settings(max_examples=6, deadline=None)
    @given(arch=ARCHS, seed=st.integers(0, 3))
    def test_pallas_grads_match_jnp(self, arch, seed):
        params, x, y = _data(arch, 8, seed)
        out_p = model.train_step(params, x, y, kernel="pallas")
        out_j = model.train_step(params, x, y, kernel="jnp")
        assert len(out_p) == len(params) + 1 == len(out_j)
        assert_allclose(out_p[0], out_j[0], rtol=1e-5, atol=1e-7)
        for gp, gj in zip(out_p[1:], out_j[1:]):
            assert_allclose(gp, gj, rtol=3e-5, atol=3e-6)

    def test_grads_match_finite_differences(self):
        arch = (3, 5, 4)
        params, x, y = _data(arch, 8, seed=7)
        outs = model.train_step(params, x, y, kernel="pallas")
        grads = outs[1:]
        eps = 1e-3
        rng = np.random.default_rng(0)
        for pi in range(len(params)):
            flat = np.asarray(params[pi]).ravel()
            for _ in range(3):  # spot-check a few coordinates
                idx = int(rng.integers(flat.size))
                for sign, store in ((+1, "hi"), (-1, "lo")):
                    pert = flat.copy()
                    pert[idx] += sign * eps
                    trial = list(params)
                    trial[pi] = jnp.asarray(pert.reshape(params[pi].shape))
                    val = float(model.mse_loss(trial, x, y, kernel="jnp"))
                    if store == "hi":
                        hi = val
                    else:
                        lo = val
                fd = (hi - lo) / (2 * eps)
                an = float(np.asarray(grads[pi]).ravel()[idx])
                assert abs(fd - an) < 5e-3 * max(1.0, abs(fd)), (pi, idx, fd, an)

    def test_loss_decreases_under_sgd(self):
        # End-to-end sanity: a few plain SGD steps reduce the pallas loss.
        arch = (4, 8, 6)
        params, x, y = _data(arch, 16, seed=3)
        lr = 0.05
        losses = []
        for _ in range(15):
            outs = model.train_step(params, x, y, kernel="pallas")
            losses.append(float(outs[0]))
            params = [p - lr * g for p, g in zip(params, outs[1:])]
        assert losses[-1] < losses[0] * 0.9


class TestAotLowering:
    def test_train_step_lowers_to_hlo_text(self):
        from compile import aot

        fn, specs = model.train_step_fn((4, 8, 6), 16, kernel="pallas")
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert len(text) > 1000

    def test_predict_lowers_to_hlo_text(self):
        from compile import aot

        fn, specs = model.predict_fn((4, 8, 6), 16, kernel="jnp")
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
