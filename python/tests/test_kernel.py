"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (including non-tile-multiple raggedness) and value
scales; assert_allclose at f32 tolerance is the core correctness signal.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import fused_dense as K
from compile.kernels import ref

DIM = st.integers(min_value=1, max_value=70)
SCALE = st.sampled_from([1e-3, 1.0, 30.0])


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(key, shape, jnp.float32)


def _keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=DIM, k=DIM, n=DIM, scale=SCALE)
    def test_matches_oracle(self, m, k, n, scale):
        ka, kb = _keys(2, seed=m * 1000 + k * 10 + n)
        x, w = _rand(ka, (m, k), scale), _rand(kb, (k, n), scale)
        got = K.matmul(x, w)
        want = ref.matmul(x, w)
        assert got.shape == (m, n)
        assert_allclose(got, want, rtol=1e-5, atol=1e-5 * scale * scale)

    def test_tile_multiple_shapes(self):
        ka, kb = _keys(2)
        x, w = _rand(ka, (256, 128)), _rand(kb, (128, 384))
        assert_allclose(K.matmul(x, w), ref.matmul(x, w), rtol=1e-5, atol=1e-4)

    def test_single_row_col(self):
        ka, kb = _keys(2)
        x, w = _rand(ka, (1, 5)), _rand(kb, (5, 1))
        assert_allclose(K.matmul(x, w), ref.matmul(x, w), rtol=1e-5, atol=1e-6)


class TestFusedDense:
    @settings(max_examples=20, deadline=None)
    @given(m=DIM, k=DIM, n=DIM)
    def test_activation_matches_oracle(self, m, k, n):
        ka, kb, kc = _keys(3, seed=m + 100 * k + 10000 * n)
        x, w, b = _rand(ka, (m, k)), _rand(kb, (k, n)), _rand(kc, (n,))
        got = K.fused_dense(x, w, b)
        want, _ = ref.fused_dense(x, w, b)
        assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_preactivation_residual(self):
        ka, kb, kc = _keys(3)
        x, w, b = _rand(ka, (33, 17)), _rand(kb, (17, 29)), _rand(kc, (29,))
        act, pre = K._fused_dense_pallas(x, w, b)
        want_act, want_pre = ref.fused_dense(x, w, b)
        assert_allclose(act, want_act, rtol=1e-5, atol=1e-5)
        assert_allclose(pre, want_pre, rtol=1e-5, atol=1e-5)

    def test_activation_bounded(self):
        # soft-sign maps into (-1, 1) — even for huge pre-activations.
        ka, kb, kc = _keys(3)
        x, w, b = _rand(ka, (8, 8), 100.0), _rand(kb, (8, 8), 100.0), _rand(kc, (8,))
        act = K.fused_dense(x, w, b)
        assert np.all(np.abs(np.asarray(act)) < 1.0)

    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(2, 33), k=st.integers(2, 33), n=st.integers(2, 33))
    def test_gradients_match_oracle(self, m, k, n):
        ka, kb, kc, kd = _keys(4, seed=m * 7 + k * 3 + n)
        x, w, b = _rand(ka, (m, k)), _rand(kb, (k, n)), _rand(kc, (n,))
        ct = _rand(kd, (m, n))

        def pallas_scalar(x, w, b):
            return jnp.sum(K.fused_dense(x, w, b) * ct)

        def ref_scalar(x, w, b):
            return jnp.sum(ref.fused_dense(x, w, b)[0] * ct)

        g_pallas = jax.grad(pallas_scalar, argnums=(0, 1, 2))(x, w, b)
        g_ref = jax.grad(ref_scalar, argnums=(0, 1, 2))(x, w, b)
        for got, want in zip(g_pallas, g_ref):
            assert_allclose(got, want, rtol=2e-5, atol=2e-5)


class TestSoftsignBwd:
    @settings(max_examples=15, deadline=None)
    @given(m=DIM, n=DIM, scale=SCALE)
    def test_matches_formula(self, m, n, scale):
        ka, kb = _keys(2, seed=m * 97 + n)
        z, da = _rand(ka, (m, n), scale), _rand(kb, (m, n))
        got = K.softsign_bwd(z, da)
        want = da * ref.softsign_grad(z)
        assert_allclose(got, want, rtol=1e-5, atol=1e-6)


class TestLinear:
    @settings(max_examples=15, deadline=None)
    @given(m=DIM, k=DIM, n=DIM)
    def test_value_and_grad(self, m, k, n):
        ka, kb, kc, kd = _keys(4, seed=m + k + n)
        x, w, b = _rand(ka, (m, k)), _rand(kb, (k, n)), _rand(kc, (n,))
        ct = _rand(kd, (m, n))
        assert_allclose(
            K.linear(x, w, b), ref.dense(x, w, b), rtol=1e-5, atol=1e-5
        )
        g = jax.grad(lambda x, w, b: jnp.sum(K.linear(x, w, b) * ct), (0, 1, 2))(
            x, w, b
        )
        gr = jax.grad(
            lambda x, w, b: jnp.sum(ref.dense(x, w, b) * ct), (0, 1, 2)
        )(x, w, b)
        for got, want in zip(g, gr):
            assert_allclose(got, want, rtol=2e-5, atol=2e-5)


class TestGram:
    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(1, 400),
        m=st.integers(1, 21),
        panel=st.sampled_from([8, 64, 1024]),
    )
    def test_matches_oracle(self, n, m, panel):
        (ka,) = _keys(1, seed=n * 31 + m)
        s = _rand(ka, (n, m))
        got = K.gram(s, panel_rows=panel)
        want = ref.gram(s)
        assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_symmetry_and_psd_diag(self):
        (ka,) = _keys(1)
        s = _rand(ka, (333, 14))
        g = np.asarray(K.gram(s))
        assert_allclose(g, g.T, rtol=1e-6, atol=1e-6)
        assert np.all(np.diag(g) >= 0.0)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 300), ma=st.integers(1, 20), mb=st.integers(1, 20))
    def test_cross_gram(self, n, ma, mb):
        ka, kb = _keys(2, seed=n + ma * 53 + mb)
        a, b = _rand(ka, (n, ma)), _rand(kb, (n, mb))
        got = K.cross_gram(a, b)
        want = ref.cross_gram(a, b)
        assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_cross_gram_of_self_is_gram(self):
        (ka,) = _keys(1)
        s = _rand(ka, (128, 9))
        assert_allclose(K.cross_gram(s, s), K.gram(s), rtol=1e-5, atol=1e-5)
